//! The encoded index: codes + codebooks + ICQ search parameters.
//!
//! Built either from a rust-trained quantizer ([`EncodedIndex::build`])
//! or from a python-trained AOT bundle ([`EncodedIndex::from_bundle`]).
//! The same structure serves baseline ADC search (fast_k = K, sigma = 0)
//! and ICQ two-step search.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::blocked::{BlockedCodes, BlockedStore, CodeUnit};
use super::lut::LutContext;
use crate::core::{Matrix, Metric};
use crate::data::format::{Tensor, TensorPack};
use crate::data::loader::TrainedBundle;
use crate::data::mapped::{CowSlice, MappedPack};
use crate::quantizer::icq::Icq;
use crate::quantizer::{Codebooks, Codes, Quantizer};

/// Structural invariants every snapshot-built index must satisfy before
/// the search state is assembled: codes inside `[0, m)` with `m` within
/// the u16 code width, `fast_k` in `[1, K]`, labels matching `n`.
/// Violations mean a corrupt or hand-tampered snapshot; failing here
/// (with an error) beats wrapping codes into a silently wrong index or
/// panicking later inside `Lut::partial_sum`. The single implementation
/// behind both loaders — [`EncodedIndex::from_pack`] directly, and
/// `TrainedBundle::validate` (hence [`EncodedIndex::from_bundle`]) for
/// the bundle path.
pub(crate) fn validate_snapshot(
    codes: &[i32],
    n: usize,
    k: usize,
    m: usize,
    fast_k: i64,
    labels_len: usize,
) -> Result<()> {
    ensure!(
        m <= <u16 as CodeUnit>::MAX_M,
        "codebook size m={m} exceeds the u16 code width"
    );
    if let Some(pos) = codes.iter().position(|&c| c < 0 || c as usize >= m)
    {
        anyhow::bail!(
            "code {} at flat index {pos} is outside [0, {m})",
            codes[pos]
        );
    }
    ensure!(
        fast_k >= 1 && fast_k as usize <= k,
        "fast_k={fast_k} outside [1, K={k}]"
    );
    ensure!(labels_len == n, "labels length {labels_len} != n={n}");
    Ok(())
}

/// An immutable, searchable encoded database.
#[derive(Clone, Debug)]
pub struct EncodedIndex {
    /// `Arc`-shared so [`EncodedIndex::slice`] (hence every shard of a
    /// `ShardedIndex`) reuses one copy of the codebook state instead of
    /// duplicating `K * m * d` floats per shard.
    codebooks: Arc<Codebooks>,
    /// row-major codes: the encoder output, the refine step's layout,
    /// and the serial parity oracle's scan order.
    codes: Codes,
    /// book-major blocked transpose of `codes` (see [`super::blocked`]):
    /// the layout every dense scan sweeps, stored at the narrowest code
    /// width the codebook size allows (u8 when m <= 256, u16 otherwise).
    blocked: BlockedStore,
    /// `Arc`-shared for the same reason as `codebooks`: it is derived
    /// from them alone, so slices share it.
    lut_ctx: Arc<LutContext>,
    /// leading fast-group size (|K|); == k for non-ICQ methods.
    pub fast_k: usize,
    /// crude margin sigma (eq. 11); 0 for non-ICQ methods.
    pub sigma: f32,
    /// Distance/similarity regime the index serves. Drives the bound
    /// direction of every search path (L2 lower-bound chain vs the
    /// similarity upper-bound mirror), the top-k ordering, and the
    /// sentinel filtered rows are masked to. Stamped into both snapshot
    /// containers; tagless (pre-metric) snapshots load as [`Metric::L2`].
    pub metric: Metric,
    /// labels of the encoded vectors (for MAP evaluation). Owned on the
    /// construction paths; a zero-copy view of the file on the
    /// mapped-snapshot open path.
    pub labels: CowSlice<i32>,
}

impl EncodedIndex {
    /// Assemble the derived search state (LUT context + blocked codes)
    /// around a codes/codebooks pair. Every constructor funnels here so
    /// the blocked transpose exists on all paths (train, bundle, pack),
    /// and the code width is chosen in exactly one place: u8 blocks when
    /// `m <= 256` (every shipped config), u16 above.
    fn assemble(
        codebooks: Codebooks,
        codes: Codes,
        fast_k: usize,
        sigma: f32,
        metric: Metric,
        labels: Vec<i32>,
    ) -> Self {
        let codebooks = Arc::new(codebooks);
        let lut_ctx = Arc::new(LutContext::new(&codebooks));
        Self::assemble_shared(
            codebooks,
            lut_ctx,
            codes,
            fast_k,
            sigma,
            metric,
            labels.into(),
        )
    }

    /// [`Self::assemble`] with already-shared codebook state — the slice
    /// path, where rebuilding the (codes-independent) LUT context and
    /// cloning the codebooks per shard would multiply memory and build
    /// time by the shard count.
    pub(crate) fn assemble_shared(
        codebooks: Arc<Codebooks>,
        lut_ctx: Arc<LutContext>,
        codes: Codes,
        fast_k: usize,
        sigma: f32,
        metric: Metric,
        labels: CowSlice<i32>,
    ) -> Self {
        let blocked = BlockedStore::from_codes(&codes, codebooks.m());
        EncodedIndex {
            codebooks,
            codes,
            blocked,
            lut_ctx,
            fast_k,
            sigma,
            metric,
            labels,
        }
    }

    /// [`Self::assemble_shared`] with the blocked store supplied by the
    /// caller instead of rebuilt from the row-major codes — the
    /// mapped-snapshot open path, where the file already holds the
    /// block-major transpose and rebuilding it would copy (and fault
    /// in) every code page the zero-copy open exists to avoid.
    pub(crate) fn assemble_from_parts(
        codebooks: Arc<Codebooks>,
        lut_ctx: Arc<LutContext>,
        codes: Codes,
        blocked: BlockedStore,
        fast_k: usize,
        sigma: f32,
        metric: Metric,
        labels: CowSlice<i32>,
    ) -> Result<Self> {
        ensure!(
            blocked.n() == codes.n() && blocked.k() == codes.k(),
            "blocked store shape [{}, {}] != codes shape [{}, {}]",
            blocked.n(),
            blocked.k(),
            codes.n(),
            codes.k()
        );
        ensure!(
            fast_k >= 1 && fast_k <= codebooks.k(),
            "fast_k={fast_k} outside [1, K={}]",
            codebooks.k()
        );
        ensure!(
            labels.len() == codes.n(),
            "labels length {} != n={}",
            labels.len(),
            codes.n()
        );
        Ok(EncodedIndex {
            codebooks,
            codes,
            blocked,
            lut_ctx,
            fast_k,
            sigma,
            metric,
            labels,
        })
    }

    /// Encode `x` with any trained quantizer. For ICQ models the fast
    /// group / sigma come from the trainer; other methods get fast_k = K
    /// (their search is the conventional full ADC). Like every
    /// constructor, funnels through the internal `assemble` step that
    /// derives the search state (LUT context + blocked transpose at the
    /// auto-selected code width).
    ///
    /// # Examples
    ///
    /// ```
    /// use icq::core::{Matrix, Rng};
    /// use icq::index::{search_adc, EncodedIndex, OpCounter};
    /// use icq::quantizer::pq::{Pq, PqOpts};
    ///
    /// let mut rng = Rng::new(0);
    /// let x = Matrix::from_fn(200, 8, |_, _| rng.normal_f32());
    /// let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 3, seed: 0 });
    /// let index = EncodedIndex::build(&pq, &x, vec![0; 200]);
    /// assert_eq!(index.len(), 200);
    /// assert_eq!(index.blocked().code_width_bits(), 8); // m <= 256
    ///
    /// let hits = search_adc::search(&index, x.row(7), 5, &OpCounter::new());
    /// assert_eq!(hits.len(), 5);
    /// assert!(hits.windows(2).all(|w| w[0].dist <= w[1].dist));
    /// ```
    pub fn build<Q: Quantizer>(q: &Q, x: &Matrix, labels: Vec<i32>) -> Self {
        assert_eq!(x.rows(), labels.len());
        if let Err(e) = check_finite_rows(x) {
            panic!("{e}");
        }
        let codes = q.encode(x);
        let codebooks = q.codebooks().clone();
        let fast_k = codebooks.k();
        Self::assemble(codebooks, codes, fast_k, 0.0, Metric::L2, labels)
    }

    /// The same index re-tagged to serve `metric`. This flips the
    /// search regime (bound direction, top-k order, filter sentinel);
    /// it does not re-encode — cosine indexes must be built over rows
    /// the caller normalized before training/encoding.
    pub fn with_metric(mut self, metric: Metric) -> Self {
        self.metric = metric;
        self
    }

    /// Build from an ICQ model, wiring the two-step search parameters.
    pub fn build_icq(icq: &Icq, x: &Matrix, labels: Vec<i32>) -> Self {
        let mut idx = Self::build(icq, x, labels);
        idx.fast_k = icq.fast_k;
        idx.sigma = icq.sigma;
        idx
    }

    /// Materialize from a python-trained bundle (codes already computed
    /// at build time by the L2 trainer).
    pub fn from_bundle(b: &TrainedBundle) -> Result<Self> {
        // `validate` covers the snapshot invariants (code range, fast_k
        // in [1, K], label/codes lengths, m within the u16 code width)
        // plus the bundle-only psi-split check, so no second pass here.
        b.validate()?;
        let codebooks =
            Codebooks::from_vec(b.k, b.m, b.d, b.codebooks.clone());
        let data: Vec<u16> = b.codes.iter().map(|&c| c as u16).collect();
        let codes = Codes::from_vec(b.n, b.k, data);
        Ok(Self::assemble(
            codebooks,
            codes,
            b.fast_k,
            b.sigma,
            Metric::L2,
            b.labels.clone(),
        ))
    }

    /// A new standalone index over the contiguous row range
    /// `[start, end)` of this one: same codebooks and two-step search
    /// parameters (`fast_k`, `sigma`), codes and labels restricted to
    /// the range, blocked storage rebuilt for the slice; codebooks and
    /// LUT context are `Arc`-shared with this index, not copied. This is the
    /// building block of [`super::shard::ShardedIndex`] — each shard is
    /// a fully independent `EncodedIndex`, so every search executor
    /// runs on it unchanged. Hit ids from the slice are range-local;
    /// add `start` to translate them back to this index's row ids.
    pub fn slice(&self, start: usize, end: usize) -> Self {
        assert!(
            start <= end && end <= self.len(),
            "slice [{start}, {end}) out of bounds (n = {})",
            self.len()
        );
        let k = self.k();
        let codes = Codes::from_vec(
            end - start,
            k,
            self.codes.as_slice()[start * k..end * k].to_vec(),
        );
        Self::assemble_shared(
            self.codebooks.clone(),
            self.lut_ctx.clone(),
            codes,
            self.fast_k,
            self.sigma,
            self.metric,
            self.labels.slice(start..end),
        )
    }

    /// A new standalone index over an arbitrary row subset: the
    /// gather-indexed sibling of [`Self::slice`], and the building
    /// block of the IVF coarse partition (each cell is a `select` of
    /// its member rows). Codebooks and LUT context stay `Arc`-shared;
    /// codes/labels are gathered and the blocked transpose rebuilt for
    /// the subset. Hit ids from the result are subset-local (`i` maps
    /// to `rows[i]`).
    ///
    /// Callers that rely on the canonical `(distance, id)` tie-break
    /// agreeing with the parent index must pass `rows` in ascending
    /// order, so subset-local order is monotone in parent row order
    /// (the IVF bitwise-parity invariant).
    pub fn select(&self, rows: &[u32]) -> Self {
        let k = self.k();
        let src = self.codes.as_slice();
        let mut data = Vec::with_capacity(rows.len() * k);
        let mut labels = Vec::with_capacity(rows.len());
        for &r in rows {
            let r = r as usize;
            assert!(
                r < self.len(),
                "select row {r} out of bounds (n = {})",
                self.len()
            );
            data.extend_from_slice(&src[r * k..(r + 1) * k]);
            labels.push(self.labels[r]);
        }
        let codes = Codes::from_vec(rows.len(), k, data);
        Self::assemble_shared(
            self.codebooks.clone(),
            self.lut_ctx.clone(),
            codes,
            self.fast_k,
            self.sigma,
            self.metric,
            labels.into(),
        )
    }

    /// Encoded vectors in the database.
    #[inline]
    pub fn len(&self) -> usize {
        self.codes.n()
    }

    /// Whether the database holds no vectors.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of codebooks (K).
    #[inline]
    pub fn k(&self) -> usize {
        self.codebooks.k()
    }

    /// Codewords per book (m).
    #[inline]
    pub fn m(&self) -> usize {
        self.codebooks.m()
    }

    /// Query/vector dimensionality.
    #[inline]
    pub fn dim(&self) -> usize {
        self.codebooks.d()
    }

    /// The codebooks (full-d layout, shared by every method).
    pub fn codebooks(&self) -> &Codebooks {
        &self.codebooks
    }

    /// Row-major codes: the refine step's layout and the serial parity
    /// oracle's scan order.
    pub fn codes(&self) -> &Codes {
        &self.codes
    }

    /// Book-major blocked codes (the dense-scan layout), at the width
    /// selected by [`BlockedStore::from_codes`].
    pub fn blocked(&self) -> &BlockedStore {
        &self.blocked
    }

    /// Precomputed query-independent LUT state (built once per index).
    pub fn lut_ctx(&self) -> &LutContext {
        &self.lut_ctx
    }

    /// Code length in bits (the paper's x-axis).
    pub fn code_bits(&self) -> usize {
        self.codes.code_bits(self.m())
    }

    /// Serialize to an icqfmt pack (index snapshots).
    pub fn to_pack(&self) -> TensorPack {
        let mut pack = TensorPack::new();
        self.codebooks.to_pack(&mut pack, "");
        let codes_i32: Vec<i32> =
            self.codes.as_slice().iter().map(|&c| c as i32).collect();
        pack.insert_i32(
            "codes",
            vec![self.codes.n(), self.codes.k()],
            codes_i32,
        );
        pack.insert_i32("fast_k", vec![1], vec![self.fast_k as i32]);
        pack.insert_f32("sigma", vec![1], vec![self.sigma]);
        pack.insert_i32("metric", vec![1], vec![self.metric.as_i32()]);
        pack.insert_i32(
            "labels",
            vec![self.labels.len()],
            self.labels.to_vec(),
        );
        pack
    }

    /// Load an index snapshot produced by [`EncodedIndex::to_pack`].
    /// Rejects structurally corrupt snapshots (out-of-range codes,
    /// `fast_k` outside `[1, K]`, label/codes length mismatch) with an
    /// error instead of building a silently wrong index.
    pub fn from_pack(pack: &TensorPack) -> Result<Self> {
        let codebooks = Codebooks::from_pack(pack, "")?;
        let (dims, codes_i32) = pack.i32("codes")?;
        ensure!(dims.len() == 2, "codes must be [n, K]");
        ensure!(
            dims[1] == codebooks.k(),
            "codes have {} books but the codebooks have {}",
            dims[1],
            codebooks.k()
        );
        let fast_k = pack.scalar_i32("fast_k")?;
        let sigma = pack.scalar_f32("sigma")?;
        let metric = metric_from_pack(pack)?;
        let (_, labels) = pack.i32("labels")?;
        validate_snapshot(
            codes_i32,
            dims[0],
            codebooks.k(),
            codebooks.m(),
            fast_k as i64,
            labels.len(),
        )?;
        let codes = Codes::from_vec(
            dims[0],
            dims[1],
            codes_i32.iter().map(|&c| c as u16).collect(),
        );
        Ok(Self::assemble(
            codebooks,
            codes,
            fast_k as usize,
            sigma,
            metric,
            labels.to_vec(),
        ))
    }

    /// Serialize to the tensor set the icqfmt2 mapped container stores
    /// for a flat index. Unlike [`Self::to_pack`] (v1: i32 row-major
    /// codes only, blocked transpose rebuilt at load), this writes the
    /// codes at their native u16 width *plus* the block-major transpose
    /// at its selected width, so a mapped open adopts both in place
    /// without copying or re-deriving anything O(n).
    pub fn to_mapped_tensors(&self) -> TensorPack {
        let mut pack = TensorPack::new();
        self.codebooks.to_pack(&mut pack, "");
        pack.tensors.insert(
            "codes".into(),
            Tensor::U16 {
                dims: vec![self.codes.n(), self.codes.k()],
                data: self.codes.as_slice().to_vec(),
            },
        );
        pack.insert_i32("fast_k", vec![1], vec![self.fast_k as i32]);
        pack.insert_f32("sigma", vec![1], vec![self.sigma]);
        pack.insert_i32("metric", vec![1], vec![self.metric.as_i32()]);
        pack.insert_i32(
            "labels",
            vec![self.labels.len()],
            self.labels.to_vec(),
        );
        pack.insert_i32(
            "blocked_width",
            vec![1],
            vec![self.blocked.code_width_bits() as i32],
        );
        pack.insert_i32(
            "blocked_block",
            vec![1],
            vec![self.blocked.block_size() as i32],
        );
        blocked_to_tensors(&self.blocked, &mut pack, "");
        pack
    }

    /// Parse + validate the codebook tensor of a mapped snapshot and
    /// build the derived LUT context — the only O(K m d) copy a mapped
    /// open performs (n-independent; the LUT context depends on the
    /// codebooks alone).
    pub(crate) fn codebooks_from_mapped(
        mp: &MappedPack,
    ) -> Result<(Arc<Codebooks>, Arc<LutContext>)> {
        let (dims, cb) = mp.segment::<f32>("codebooks")?;
        ensure!(dims.len() == 3, "codebooks must be [K, m, d]");
        ensure!(
            dims.iter().all(|&v| v >= 1),
            "codebooks dims {dims:?} contain a zero axis"
        );
        ensure!(
            dims[1] <= <u16 as CodeUnit>::MAX_M,
            "codebook size m={} exceeds the u16 code width",
            dims[1]
        );
        let codebooks = Arc::new(Codebooks::from_vec(
            dims[0],
            dims[1],
            dims[2],
            cb.to_vec(),
        ));
        let lut_ctx = Arc::new(LutContext::new(&codebooks));
        Ok((codebooks, lut_ctx))
    }

    /// Open a flat index from a mapped icqfmt2 snapshot (written by
    /// [`Self::to_mapped_tensors`]): codebooks and the derived LUT
    /// context are copied (small, n-free), while the row-major codes,
    /// labels, and blocked transpose become zero-copy views of the
    /// file. Structural shape checks run here once; code *values* are
    /// not scanned — scanning would fault in every payload page and
    /// defeat the zero-copy open (see the trust model in
    /// [`crate::data::mapped`]; the scan kernels index LUT rows with
    /// bounds-checked or masked lookups, so lying code values can
    /// mis-score or panic a search, never corrupt memory).
    pub fn from_mapped(mp: &MappedPack) -> Result<Self> {
        let (codebooks, lut_ctx) = Self::codebooks_from_mapped(mp)?;
        let (k, m) = (codebooks.k(), codebooks.m());
        let (cdims, codes_seg) = mp.segment::<u16>("codes")?;
        ensure!(cdims.len() == 2, "codes must be [n, K]");
        ensure!(
            cdims[1] == k,
            "codes have {} books but the codebooks have {k}",
            cdims[1]
        );
        let n = cdims[0];
        let codes = Codes::from_cow(n, k, CowSlice::Mapped(codes_seg))?;
        let (ldims, labels_seg) = mp.segment::<i32>("labels")?;
        ensure!(
            ldims == [n].as_slice(),
            "labels must be [n={n}], got {ldims:?}"
        );
        let fast_k = mp.scalar_i32("fast_k")?;
        let sigma = mp.scalar_f32("sigma")?;
        let metric = metric_from_mapped(mp)?;
        let width = mp.scalar_i32("blocked_width")?;
        let block = mp.scalar_i32("blocked_block")?;
        let blocked = blocked_from_mapped(mp, "", n, k, m, width, block)?;
        ensure!(
            fast_k >= 1 && fast_k as usize <= k,
            "fast_k={fast_k} outside [1, K={k}]"
        );
        Self::assemble_from_parts(
            codebooks,
            lut_ctx,
            codes,
            blocked,
            fast_k as usize,
            sigma,
            metric,
            CowSlice::Mapped(labels_seg),
        )
    }
}

/// Reject base matrices holding non-finite components. A NaN row would
/// poison every LUT partial sum it touches and — worse — break the
/// `total_cmp` top-k ordering every search path assumes, returning
/// silently wrong neighbors long after the build. Failing the build
/// loudly mirrors the query-side check at the serving boundary.
pub(crate) fn check_finite_rows(x: &Matrix) -> Result<()> {
    for i in 0..x.rows() {
        let row = x.row(i);
        if let Some(j) = row.iter().position(|v| !v.is_finite()) {
            anyhow::bail!(
                "base vector {i} component {j} is non-finite ({})",
                row[j]
            );
        }
    }
    Ok(())
}

/// Decode the optional `metric` scalar of a v1 snapshot. Tagless
/// snapshots predate metrics and load as L2; a present-but-unknown tag
/// is corruption and errors.
fn metric_from_pack(pack: &TensorPack) -> Result<Metric> {
    if !pack.tensors.contains_key("metric") {
        return Ok(Metric::L2);
    }
    let tag = pack.scalar_i32("metric")?;
    Metric::from_i32(tag)
        .ok_or_else(|| anyhow::anyhow!("unknown metric tag {tag} in snapshot"))
}

/// [`metric_from_pack`] for the icqfmt2 mapped container.
pub(crate) fn metric_from_mapped(mp: &MappedPack) -> Result<Metric> {
    if !mp.contains("metric") {
        return Ok(Metric::L2);
    }
    let tag = mp.scalar_i32("metric")?;
    Metric::from_i32(tag)
        .ok_or_else(|| anyhow::anyhow!("unknown metric tag {tag} in snapshot"))
}

/// Insert the block-major transpose of `store` into `pack` under
/// `{prefix}blocked_u8` / `{prefix}blocked_u16` (name picked by its
/// width), dims `[nb, K, B]` — tail padding lanes included, exactly the
/// array a mapped open adopts in place.
pub(crate) fn blocked_to_tensors(
    store: &BlockedStore,
    pack: &mut TensorPack,
    prefix: &str,
) {
    let dims = vec![store.num_blocks(), store.k(), store.block_size()];
    match store {
        BlockedStore::U8(b) => {
            pack.tensors.insert(
                format!("{prefix}blocked_u8"),
                Tensor::U8 { dims, data: b.raw().to_vec() },
            );
        }
        BlockedStore::U16(b) => {
            pack.tensors.insert(
                format!("{prefix}blocked_u16"),
                Tensor::U16 { dims, data: b.raw().to_vec() },
            );
        }
    }
}

/// Adopt a `{prefix}blocked_*` segment of a mapped snapshot as a
/// zero-copy [`BlockedStore`] for an `n x K` code table over codebook
/// size `m`. `width` and `block` come from the snapshot's scalars; the
/// width must match the owned loaders' selection rule (u8 iff
/// `m <= 256`) so a mapped open yields the same store variant — and
/// thus the same kernels and bitwise-identical scans — as an owned
/// load of the same index.
pub(crate) fn blocked_from_mapped(
    mp: &MappedPack,
    prefix: &str,
    n: usize,
    k: usize,
    m: usize,
    width: i32,
    block: i32,
) -> Result<BlockedStore> {
    let expect_width =
        if m <= <u8 as CodeUnit>::MAX_M { 8i32 } else { 16i32 };
    ensure!(
        width == expect_width,
        "blocked_width={width} but m={m} selects {expect_width}-bit codes"
    );
    ensure!(block >= 1, "blocked_block={block} must be >= 1");
    let block = block as usize;
    let nb = n.div_ceil(block);
    let want = [nb, k, block];
    if width == 8 {
        let name = format!("{prefix}blocked_u8");
        let (dims, seg) = mp.segment::<u8>(&name)?;
        ensure!(
            dims == want.as_slice(),
            "{name} dims {dims:?} != [nb={nb}, K={k}, B={block}]"
        );
        Ok(BlockedStore::U8(BlockedCodes::from_parts(
            n,
            k,
            block,
            CowSlice::Mapped(seg),
        )?))
    } else {
        let name = format!("{prefix}blocked_u16");
        let (dims, seg) = mp.segment::<u16>(&name)?;
        ensure!(
            dims == want.as_slice(),
            "{name} dims {dims:?} != [nb={nb}, K={k}, B={block}]"
        );
        Ok(BlockedStore::U16(BlockedCodes::from_parts(
            n,
            k,
            block,
            CowSlice::Mapped(seg),
        )?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;
    use crate::quantizer::icq::IcqOpts;
    use crate::quantizer::pq::{Pq, PqOpts};

    fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, j| {
            let scale = if j % 3 == 0 { 4.0 } else { 0.3 };
            rng.normal_f32() * scale
        })
    }

    #[test]
    fn build_from_pq_has_trivial_icq_params() {
        let x = hetero(100, 6, 1);
        let pq = Pq::train(&x, PqOpts { k: 3, m: 4, iters: 5, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 100]);
        assert_eq!(idx.fast_k, 3);
        assert_eq!(idx.sigma, 0.0);
        assert_eq!(idx.len(), 100);
        assert_eq!(idx.code_bits(), 6); // 3 books x 2 bits
    }

    #[test]
    fn build_from_icq_wires_parameters() {
        let x = hetero(200, 9, 2);
        let icq = Icq::train(
            &x,
            IcqOpts { k: 3, m: 8, fast_k: 1, kmeans_iters: 5, prior_steps: 100, seed: 0 },
        );
        let idx = EncodedIndex::build_icq(&icq, &x, vec![1; 200]);
        assert_eq!(idx.fast_k, 1);
        assert!(idx.sigma > 0.0);
    }

    #[test]
    fn blocked_transpose_built_on_every_constructor() {
        let x = hetero(70, 6, 4);
        let pq = Pq::train(&x, PqOpts { k: 3, m: 4, iters: 4, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 70]);
        assert_eq!(idx.blocked().n(), idx.len());
        assert_eq!(idx.blocked().k(), idx.k());
        // m = 4 <= 256: the narrow store must have been selected
        assert_eq!(idx.blocked().code_width_bits(), 8);
        for i in 0..idx.len() {
            for kk in 0..idx.k() {
                assert_eq!(idx.blocked().get(i, kk), idx.codes().get(i, kk));
            }
        }
        let back = EncodedIndex::from_pack(&idx.to_pack()).unwrap();
        assert_eq!(back.blocked(), idx.blocked());
    }

    #[test]
    fn from_pack_rejects_corrupt_snapshots() {
        let x = hetero(40, 6, 7);
        let pq = Pq::train(&x, PqOpts { k: 3, m: 4, iters: 4, seed: 0 });
        let idx =
            EncodedIndex::build(&pq, &x, (0..40).map(|i| i as i32).collect());
        let good = idx.to_pack();
        assert!(EncodedIndex::from_pack(&good).is_ok());

        // negative code: would wrap through `as u16` into a huge index
        let mut bad = good.clone();
        let mut codes: Vec<i32> =
            good.i32("codes").unwrap().1.to_vec();
        codes[7] = -1;
        bad.insert_i32("codes", vec![40, 3], codes);
        assert!(EncodedIndex::from_pack(&bad).is_err());

        // code == m: one past the last codeword
        let mut bad = good.clone();
        let mut codes: Vec<i32> = good.i32("codes").unwrap().1.to_vec();
        codes[0] = 4;
        bad.insert_i32("codes", vec![40, 3], codes);
        assert!(EncodedIndex::from_pack(&bad).is_err());

        // fast_k out of [1, K]
        for bad_fast_k in [0i32, 4] {
            let mut bad = good.clone();
            bad.insert_i32("fast_k", vec![1], vec![bad_fast_k]);
            assert!(
                EncodedIndex::from_pack(&bad).is_err(),
                "fast_k={bad_fast_k} accepted"
            );
        }

        // labels shorter than n
        let mut bad = good.clone();
        bad.insert_i32("labels", vec![39], vec![0; 39]);
        assert!(EncodedIndex::from_pack(&bad).is_err());
    }

    #[test]
    fn from_bundle_rejects_out_of_range_codes() {
        use crate::data::loader::TrainedBundle;
        let (k, m, d, n) = (2usize, 4usize, 6usize, 8usize);
        let xi = vec![1., 1., 1., 0., 0., 0.];
        let mut cb = vec![0.0f32; k * m * d];
        for j in 0..m {
            for dim in 0..3 {
                cb[j * d + dim] = 1.0 + j as f32; // fast cb on psi
                cb[(m + j) * d + 3 + dim] = 2.0; // slow cb off psi
            }
        }
        let base = TrainedBundle {
            codebooks: cb,
            k,
            m,
            d,
            fast_k: 1,
            xi,
            lambda: vec![0.5; d],
            sigma: 1.0,
            codes: vec![1; n * k],
            n,
            labels: vec![0; n],
            embeddings: Matrix::zeros(n, d),
            test_x: Matrix::zeros(2, d),
            test_labels: vec![0, 1],
            pack: crate::data::format::TensorPack::new(),
        };
        assert!(EncodedIndex::from_bundle(&base).is_ok());

        let mut bad = base.clone();
        bad.codes[3] = m as i32; // out of range
        assert!(EncodedIndex::from_bundle(&bad).is_err());

        let mut bad = base.clone();
        bad.codes[0] = -2;
        assert!(EncodedIndex::from_bundle(&bad).is_err());

        let mut bad = base.clone();
        bad.fast_k = k + 1;
        assert!(EncodedIndex::from_bundle(&bad).is_err());

        let mut bad = base;
        bad.labels = vec![0; n - 1];
        assert!(EncodedIndex::from_bundle(&bad).is_err());
    }

    #[test]
    fn slice_preserves_rows_params_and_labels() {
        let x = hetero(90, 9, 8);
        let icq = Icq::train(
            &x,
            IcqOpts { k: 3, m: 8, fast_k: 1, kmeans_iters: 4, prior_steps: 50, seed: 0 },
        );
        let labels: Vec<i32> = (0..90).map(|i| i as i32).collect();
        let idx = EncodedIndex::build_icq(&icq, &x, labels);
        for (start, end) in [(0usize, 90usize), (10, 70), (64, 65), (30, 30)] {
            let s = idx.slice(start, end);
            assert_eq!(s.len(), end - start);
            assert_eq!(s.fast_k, idx.fast_k);
            assert_eq!(s.sigma, idx.sigma);
            assert_eq!(s.k(), idx.k());
            assert_eq!(s.dim(), idx.dim());
            for i in 0..s.len() {
                assert_eq!(s.labels[i], idx.labels[start + i]);
                for kk in 0..idx.k() {
                    assert_eq!(
                        s.codes().get(i, kk),
                        idx.codes().get(start + i, kk)
                    );
                    assert_eq!(
                        s.blocked().get(i, kk),
                        idx.blocked().get(start + i, kk)
                    );
                }
            }
        }
    }

    #[test]
    fn select_gathers_rows_and_shares_search_state() {
        let x = hetero(80, 9, 11);
        let icq = Icq::train(
            &x,
            IcqOpts { k: 3, m: 8, fast_k: 1, kmeans_iters: 4, prior_steps: 50, seed: 0 },
        );
        let labels: Vec<i32> = (0..80).map(|i| i as i32).collect();
        let idx = EncodedIndex::build_icq(&icq, &x, labels);
        for rows in [
            vec![0u32, 3, 7, 64, 65, 79],
            vec![5u32],
            vec![],
            (0..80u32).collect::<Vec<_>>(),
        ] {
            let s = idx.select(&rows);
            assert_eq!(s.len(), rows.len());
            assert_eq!(s.fast_k, idx.fast_k);
            assert_eq!(s.sigma, idx.sigma);
            assert_eq!(s.k(), idx.k());
            assert_eq!(s.dim(), idx.dim());
            for (i, &r) in rows.iter().enumerate() {
                assert_eq!(s.labels[i], idx.labels[r as usize]);
                for kk in 0..idx.k() {
                    assert_eq!(
                        s.codes().get(i, kk),
                        idx.codes().get(r as usize, kk)
                    );
                    assert_eq!(
                        s.blocked().get(i, kk),
                        idx.blocked().get(r as usize, kk)
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn select_rejects_out_of_range_row() {
        let x = hetero(20, 6, 12);
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 3, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 20]);
        let _ = idx.select(&[3, 20]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_rejects_reversed_range() {
        let x = hetero(20, 6, 9);
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 3, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 20]);
        let _ = idx.slice(10, 5);
    }

    #[test]
    fn mapped_tensors_roundtrip_adopts_views() {
        let x = hetero(130, 6, 3); // 130 % 64 != 0: tail block exercised
        let icq = Icq::train(
            &x,
            IcqOpts { k: 2, m: 4, fast_k: 1, kmeans_iters: 4, prior_steps: 50, seed: 0 },
        );
        let labels: Vec<i32> = (0..130).map(|i| i as i32 % 4).collect();
        let idx = EncodedIndex::build_icq(&icq, &x, labels);
        let bytes =
            crate::data::mapped::write_mapped(&idx.to_mapped_tensors());
        let mp = MappedPack::from_bytes(&bytes).unwrap();
        let back = EncodedIndex::from_mapped(&mp).unwrap();
        assert_eq!(back.codes(), idx.codes());
        assert_eq!(back.blocked(), idx.blocked());
        assert_eq!(back.labels, idx.labels);
        assert_eq!(back.fast_k, idx.fast_k);
        assert_eq!(back.sigma, idx.sigma);
        // codes/labels/blocked are views of the image, not copies
        assert!(back.blocked().is_mapped());
        assert!(back.labels.is_mapped());
        assert!(!idx.blocked().is_mapped());
    }

    #[test]
    fn from_mapped_rejects_structural_corruption() {
        fn reopen(pack: &TensorPack) -> Result<EncodedIndex> {
            let bytes = crate::data::mapped::write_mapped(pack);
            EncodedIndex::from_mapped(&MappedPack::from_bytes(&bytes)?)
        }
        let x = hetero(20, 6, 5);
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 3, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 20]);
        let good = idx.to_mapped_tensors();
        assert!(reopen(&good).is_ok());

        // wrong blocked width for m (m=4 selects u8)
        let mut bad = good.clone();
        bad.insert_i32("blocked_width", vec![1], vec![16]);
        assert!(reopen(&bad).is_err());

        // fast_k out of [1, K]
        for bad_fast_k in [0i32, 3] {
            let mut bad = good.clone();
            bad.insert_i32("fast_k", vec![1], vec![bad_fast_k]);
            assert!(reopen(&bad).is_err(), "fast_k={bad_fast_k} accepted");
        }

        // labels shorter than n
        let mut bad = good.clone();
        bad.insert_i32("labels", vec![19], vec![0; 19]);
        assert!(reopen(&bad).is_err());

        // blocked transpose missing entirely
        let mut bad = good.clone();
        bad.tensors.remove("blocked_u8");
        assert!(reopen(&bad).is_err());
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn build_rejects_nan_base_rows() {
        let mut x = hetero(30, 6, 21);
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 3, seed: 0 });
        x.set(17, 3, f32::NAN);
        let _ = EncodedIndex::build(&pq, &x, vec![0; 30]);
    }

    #[test]
    fn metric_tag_round_trips_and_tagless_loads_as_l2() {
        use crate::core::Metric;
        let x = hetero(50, 6, 14);
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 3, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 50])
            .with_metric(Metric::InnerProduct);
        assert_eq!(idx.slice(5, 20).metric, Metric::InnerProduct);
        assert_eq!(idx.select(&[1, 7]).metric, Metric::InnerProduct);

        // v1 pack container
        let back = EncodedIndex::from_pack(&idx.to_pack()).unwrap();
        assert_eq!(back.metric, Metric::InnerProduct);
        // icqfmt2 mapped container
        let bytes =
            crate::data::mapped::write_mapped(&idx.to_mapped_tensors());
        let mp = MappedPack::from_bytes(&bytes).unwrap();
        assert_eq!(
            EncodedIndex::from_mapped(&mp).unwrap().metric,
            Metric::InnerProduct
        );

        // tagless snapshots (both containers) load as L2
        let mut v1 = idx.to_pack();
        v1.tensors.remove("metric");
        assert_eq!(EncodedIndex::from_pack(&v1).unwrap().metric, Metric::L2);
        let mut v2 = idx.to_mapped_tensors();
        v2.tensors.remove("metric");
        let bytes = crate::data::mapped::write_mapped(&v2);
        let mp = MappedPack::from_bytes(&bytes).unwrap();
        assert_eq!(
            EncodedIndex::from_mapped(&mp).unwrap().metric,
            Metric::L2
        );

        // unknown tags are corruption, not a silent L2 fallback
        let mut bad = idx.to_pack();
        bad.insert_i32("metric", vec![1], vec![9]);
        assert!(EncodedIndex::from_pack(&bad).is_err());
    }

    #[test]
    fn pack_roundtrip_preserves_search_state() {
        let x = hetero(60, 6, 3);
        let icq = Icq::train(
            &x,
            IcqOpts { k: 2, m: 4, fast_k: 1, kmeans_iters: 4, prior_steps: 50, seed: 0 },
        );
        let idx = EncodedIndex::build_icq(&icq, &x, (0..60).map(|i| i as i32 % 4).collect());
        let pack = idx.to_pack();
        let back = EncodedIndex::from_pack(&pack).unwrap();
        assert_eq!(back.fast_k, idx.fast_k);
        assert_eq!(back.sigma, idx.sigma);
        assert_eq!(back.codes(), idx.codes());
        assert_eq!(back.labels, idx.labels);
    }
}
