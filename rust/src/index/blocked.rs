//! Block-interleaved, book-major code storage for the dense scan paths.
//!
//! The row-major [`Codes`] layout (`[n][K]` u16) is what encoders emit and
//! what the refine step wants (one vector's whole code row at a time), but
//! it is hostile to the dense crude pass: every accumulated vector strides
//! across K books, so the hardware reloads a different LUT row per add and
//! cannot vectorize the sweep. Quick ADC (André et al.) and Bolt (Blalock
//! & Guttag) fix this by transposing codes into fixed-size blocks:
//!
//! ```text
//! row-major  (Codes):        code[i][k]               i = 0..n, k = 0..K
//! blocked (BlockedCodes):    block b = [K][B] codes   b = 0..ceil(n/B)
//!                            data[(b*K + k)*B + j] = code[b*B + j][k]
//! ```
//!
//! Within a block the scan is a columnar sweep: load LUT row `k` once,
//! then add `B` contiguous code lookups into a `B`-wide accumulator —
//! a loop shape the compiler can unroll and auto-vectorize, with the LUT
//! row hot in L1 for the whole block. The tail block is padded with code
//! 0; callers copy only the first `n - b*B` lanes of the last block.
//!
//! ## Code width
//!
//! Storage is generic over the per-code integer ([`CodeUnit`]): `u8` when
//! the codebook size allows it, `u16` otherwise. The selection rule lives
//! in [`BlockedStore::from_codes`] and is applied automatically by
//! `EncodedIndex::assemble`:
//!
//! * `m <= 256` — [`BlockedCodes<u8>`]: every shipped config is in this
//!   regime (the paper's tables use m in {8..256}), and the narrow codes
//!   halve the bytes streamed per crude-pass add. The `u8` store is also
//!   the input layout of the quantized-LUT SIMD sweep in [`super::qlut`].
//! * `m > 256` — [`BlockedCodes<u16>`]: the wide fallback, up to
//!   m = 65536.
//!
//! Accumulation order per vector is books-ascending, identical to
//! [`Lut::partial_sum`] over a row-major code row, so blocked partial
//! sums are bitwise equal to the serial path — and independent of the
//! code width, since the width only changes how the same lookup index is
//! stored. The row-major scan stays around as the parity oracle (see
//! `search_adc::search_with_lut_rowmajor` and the serial
//! `search_icq::search_with_lut`).

use super::lut::Lut;
use crate::data::mapped::{CowSlice, Scalar};
use crate::quantizer::Codes;

/// Default vectors per block: 64 lanes keeps a whole block of codes
/// (K * 64 bytes at K = 8 for u8 codes) plus the accumulator inside L1
/// while giving the compiler long contiguous inner loops. 64 is also a
/// multiple of the 32-lane AVX2 stride the quantized sweep uses.
pub const DEFAULT_BLOCK: usize = 64;

/// A fixed-width unsigned integer a code can be stored in.
///
/// Implemented for `u8` (m <= 256) and `u16` (m <= 65536). The trait is
/// sealed by construction: nothing else in the crate implements it. The
/// [`crate::data::mapped::Scalar`] supertrait is what lets a store view
/// an `mmap`ed snapshot segment in place instead of owning heap memory.
pub trait CodeUnit:
    Scalar + Copy + Default + PartialEq + std::fmt::Debug + Send + Sync + 'static
{
    /// Largest codebook size this width can index (exclusive code bound).
    const MAX_M: usize;

    /// Narrow from the encoder's `u16`. Callers must have validated
    /// `c < MAX_M` (the loaders reject out-of-range codes up front).
    fn from_wide(c: u16) -> Self;

    /// Widen back to the encoder width.
    fn widen(self) -> u16;

    /// The LUT row index this code selects.
    fn index(self) -> usize;
}

impl CodeUnit for u8 {
    const MAX_M: usize = 1 << 8;

    #[inline]
    fn from_wide(c: u16) -> Self {
        debug_assert!((c as usize) < Self::MAX_M, "code {c} overflows u8");
        c as u8
    }

    #[inline]
    fn widen(self) -> u16 {
        self as u16
    }

    #[inline]
    fn index(self) -> usize {
        self as usize
    }
}

impl CodeUnit for u16 {
    const MAX_M: usize = 1 << 16;

    #[inline]
    fn from_wide(c: u16) -> Self {
        c
    }

    #[inline]
    fn widen(self) -> u16 {
        self
    }

    #[inline]
    fn index(self) -> usize {
        self as usize
    }
}

/// Codes regrouped into fixed-size blocks of `B` vectors, book-major
/// (`[K][B]`) within each block, stored at width `C`. Built once at index
/// construction from the row-major [`Codes`] — or adopted pre-transposed
/// from a mapped snapshot via [`Self::from_parts`]; immutable afterwards.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockedCodes<C: CodeUnit> {
    n: usize,
    k: usize,
    block: usize,
    /// `ceil(n / block)` blocks, each `[K][block]`; tail lanes are 0.
    /// Owned heap storage on the construction path, a zero-copy view of
    /// a mapped snapshot on the `--mmap` open path.
    data: CowSlice<C>,
}

impl<C: CodeUnit> BlockedCodes<C> {
    /// Transpose `codes` into blocks of [`DEFAULT_BLOCK`] vectors.
    pub fn from_codes(codes: &Codes) -> Self {
        Self::with_block(codes, DEFAULT_BLOCK)
    }

    /// Transpose `codes` into blocks of `block` vectors.
    pub fn with_block(codes: &Codes, block: usize) -> Self {
        assert!(block > 0, "block size must be >= 1");
        let (n, k) = (codes.n(), codes.k());
        let nb = n.div_ceil(block);
        let mut data = vec![C::default(); nb * k * block];
        for i in 0..n {
            let (b, lane) = (i / block, i % block);
            for kk in 0..k {
                data[(b * k + kk) * block + lane] =
                    C::from_wide(codes.get(i, kk));
            }
        }
        BlockedCodes { n, k, block, data: data.into() }
    }

    /// Adopt already-transposed block-major storage (the mapped-snapshot
    /// open path: the file holds the exact `[K][B]` layout this module
    /// writes, so no transpose or copy happens). `data` must hold
    /// exactly `ceil(n / block) * k * block` codes.
    pub fn from_parts(
        n: usize,
        k: usize,
        block: usize,
        data: CowSlice<C>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(block > 0, "block size must be >= 1");
        let expect = n
            .div_ceil(block)
            .checked_mul(k)
            .and_then(|x| x.checked_mul(block));
        anyhow::ensure!(
            Some(data.len()) == expect,
            "blocked storage holds {} codes; n={n} k={k} block={block} \
             needs {expect:?}",
            data.len()
        );
        Ok(BlockedCodes { n, k, block, data })
    }

    /// The raw block-major code array (serialization; layout per the
    /// module docs, tail lanes included).
    #[inline]
    pub fn raw(&self) -> &[C] {
        &self.data
    }

    /// Whether the codes view a mapped snapshot (false = owned heap).
    pub fn is_mapped(&self) -> bool {
        self.data.is_mapped()
    }

    /// Stored vectors (excluding tail padding).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Books per code row (K).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vectors per block (B).
    #[inline]
    pub fn block_size(&self) -> usize {
        self.block
    }

    /// Blocks stored: `ceil(n / B)`.
    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.n.div_ceil(self.block)
    }

    /// Book-major codes of block `b`: a `[K][B]` slice of length `K * B`.
    #[inline]
    pub fn block(&self, b: usize) -> &[C] {
        let len = self.k * self.block;
        &self.data[b * len..(b + 1) * len]
    }

    /// Number of real (non-padding) lanes in block `b`.
    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        self.block.min(self.n - b * self.block)
    }

    /// Code of vector `i` in book `kk`, widened to the encoder width.
    #[inline]
    pub fn get(&self, i: usize, kk: usize) -> u16 {
        let (b, lane) = (i / self.block, i % self.block);
        self.data[(b * self.k + kk) * self.block + lane].widen()
    }

    /// Accumulate LUT partial sums over books `[k0, k1)` for block `b`
    /// into `acc[0..B]` (overwritten). Per-book LUT row is loaded once;
    /// the inner loop adds B contiguous code lookups — the
    /// auto-vectorizable sweep the module docs describe. Padding lanes
    /// accumulate code 0 and must be ignored via [`Self::block_len`].
    pub fn block_partial_sums(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        b: usize,
        acc: &mut [f32],
    ) {
        let bs = self.block;
        debug_assert_eq!(acc.len(), bs);
        let blk = self.block(b);
        acc.fill(0.0);
        for kk in k0..k1 {
            let row = lut.row(kk);
            let codes = &blk[kk * bs..(kk + 1) * bs];
            for (a, &c) in acc.iter_mut().zip(codes) {
                *a += row[c.index()];
            }
        }
    }

    /// Dense sweep over the whole database:
    /// `out[i] = sum_{k in [k0, k1)} lut[k][code[i][k]]`.
    /// This is the blocked crude pass (`k1 = fast_k`) and the blocked
    /// full-ADC distance pass (`k0 = 0, k1 = K`).
    pub fn partial_sums_into(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), self.n);
        self.partial_sums_range_into(lut, k0, k1, 0, self.num_blocks(), out);
    }

    /// Rows covered by the block range `[b0, b1)` — the length the range
    /// sweeps write (only the final block of the store is partial).
    #[inline]
    pub fn range_rows(&self, b0: usize, b1: usize) -> usize {
        if b0 >= b1 {
            return 0;
        }
        (b1 * self.block).min(self.n) - b0 * self.block
    }

    /// [`Self::partial_sums_into`] restricted to the block range
    /// `[b0, b1)`: `out[i - b0 * B]` receives the partial sum of global
    /// row `i`. `out.len()` must equal [`Self::range_rows`]. Per-row
    /// accumulation is the identical [`Self::block_partial_sums`] loop,
    /// so a range sweep is bitwise equal to the corresponding slice of a
    /// whole-database sweep — the block-parallel single-query scan
    /// splits the store this way across scoped threads.
    pub fn partial_sums_range_into(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        b0: usize,
        b1: usize,
        out: &mut [f32],
    ) {
        assert!(b1 <= self.num_blocks(), "block range past the store");
        assert_eq!(out.len(), self.range_rows(b0, b1));
        let bs = self.block;
        let mut acc = vec![0.0f32; bs];
        for b in b0..b1 {
            self.block_partial_sums(lut, k0, k1, b, &mut acc);
            let base = (b - b0) * bs;
            let take = self.block_len(b);
            out[base..base + take].copy_from_slice(&acc[..take]);
        }
    }

    /// Multi-query dense sweep, LUT-major: the outer loop walks the code
    /// blocks ONCE, and each resident block is swept with every LUT of
    /// the batch before moving on — so a block's code bytes are streamed
    /// from memory once per *batch* instead of once per query. `out` is
    /// query-major `[luts.len()][n]` (`out[q * n + i]`).
    ///
    /// Per-(query, vector) accumulation is the same books-ascending
    /// [`Self::block_partial_sums`] loop the single-query sweep runs, so
    /// each query's row of `out` is bitwise identical to a
    /// [`Self::partial_sums_into`] call with its LUT.
    pub fn partial_sums_batch_into(
        &self,
        luts: &[Lut],
        k0: usize,
        k1: usize,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), luts.len() * self.n);
        let (n, bs) = (self.n, self.block);
        let mut acc = vec![0.0f32; bs];
        for b in 0..self.num_blocks() {
            let base = b * bs;
            let take = self.block_len(b);
            for (qi, lut) in luts.iter().enumerate() {
                self.block_partial_sums(lut, k0, k1, b, &mut acc);
                out[qi * n + base..qi * n + base + take]
                    .copy_from_slice(&acc[..take]);
            }
        }
    }
}

/// Width-erased blocked storage: the concrete [`BlockedCodes`] width an
/// index carries, chosen once at construction. Dense scans match on the
/// variant at the top of the sweep so the hot loops stay monomorphic.
#[derive(Clone, Debug, PartialEq)]
pub enum BlockedStore {
    /// Narrow store: one byte per code (`m <= 256`, every shipped
    /// config); the input layout of the quantized sweep in
    /// [`super::qlut`].
    U8(BlockedCodes<u8>),
    /// Wide fallback: two bytes per code (`256 < m <= 65536`).
    U16(BlockedCodes<u16>),
}

impl BlockedStore {
    /// The width selection rule: `u8` blocks when every code fits a byte
    /// (`m <= 256`), `u16` otherwise. `m` is the codebook size the codes
    /// were produced against; callers must have validated `code < m`.
    pub fn from_codes(codes: &Codes, m: usize) -> Self {
        if m <= <u8 as CodeUnit>::MAX_M {
            BlockedStore::U8(BlockedCodes::from_codes(codes))
        } else {
            BlockedStore::U16(BlockedCodes::from_codes(codes))
        }
    }

    /// Bits per stored code (8 or 16) — scan bandwidth per table-add.
    pub fn code_width_bits(&self) -> usize {
        match self {
            BlockedStore::U8(_) => 8,
            BlockedStore::U16(_) => 16,
        }
    }

    /// Whether the codes view a mapped snapshot (false = owned heap).
    pub fn is_mapped(&self) -> bool {
        match self {
            BlockedStore::U8(b) => b.is_mapped(),
            BlockedStore::U16(b) => b.is_mapped(),
        }
    }

    /// The narrow store, when the index selected it (`m <= 256`). The
    /// quantized-LUT sweep ([`super::qlut`]) requires byte codes.
    pub fn as_u8(&self) -> Option<&BlockedCodes<u8>> {
        match self {
            BlockedStore::U8(b) => Some(b),
            BlockedStore::U16(_) => None,
        }
    }

    /// Stored vectors (excluding tail padding).
    #[inline]
    pub fn n(&self) -> usize {
        match self {
            BlockedStore::U8(b) => b.n(),
            BlockedStore::U16(b) => b.n(),
        }
    }

    /// Books per code row (K).
    #[inline]
    pub fn k(&self) -> usize {
        match self {
            BlockedStore::U8(b) => b.k(),
            BlockedStore::U16(b) => b.k(),
        }
    }

    /// Vectors per block (B).
    #[inline]
    pub fn block_size(&self) -> usize {
        match self {
            BlockedStore::U8(b) => b.block_size(),
            BlockedStore::U16(b) => b.block_size(),
        }
    }

    /// Blocks stored: `ceil(n / B)`.
    #[inline]
    pub fn num_blocks(&self) -> usize {
        match self {
            BlockedStore::U8(b) => b.num_blocks(),
            BlockedStore::U16(b) => b.num_blocks(),
        }
    }

    /// Number of real (non-padding) lanes in block `b`.
    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        match self {
            BlockedStore::U8(s) => s.block_len(b),
            BlockedStore::U16(s) => s.block_len(b),
        }
    }

    /// Code of vector `i` in book `kk`, widened to the encoder width.
    #[inline]
    pub fn get(&self, i: usize, kk: usize) -> u16 {
        match self {
            BlockedStore::U8(b) => b.get(i, kk),
            BlockedStore::U16(b) => b.get(i, kk),
        }
    }

    /// Dense f32 sweep (see [`BlockedCodes::partial_sums_into`]); results
    /// are bitwise identical across widths.
    pub fn partial_sums_into(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        out: &mut [f32],
    ) {
        match self {
            BlockedStore::U8(b) => b.partial_sums_into(lut, k0, k1, out),
            BlockedStore::U16(b) => b.partial_sums_into(lut, k0, k1, out),
        }
    }

    /// Rows covered by the block range `[b0, b1)` (see
    /// [`BlockedCodes::range_rows`]).
    #[inline]
    pub fn range_rows(&self, b0: usize, b1: usize) -> usize {
        match self {
            BlockedStore::U8(b) => b.range_rows(b0, b1),
            BlockedStore::U16(b) => b.range_rows(b0, b1),
        }
    }

    /// Dense f32 sweep over the block range `[b0, b1)` (see
    /// [`BlockedCodes::partial_sums_range_into`]).
    #[allow(clippy::too_many_arguments)]
    pub fn partial_sums_range_into(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        b0: usize,
        b1: usize,
        out: &mut [f32],
    ) {
        match self {
            BlockedStore::U8(b) => {
                b.partial_sums_range_into(lut, k0, k1, b0, b1, out)
            }
            BlockedStore::U16(b) => {
                b.partial_sums_range_into(lut, k0, k1, b0, b1, out)
            }
        }
    }

    /// Multi-query LUT-major dense sweep (see
    /// [`BlockedCodes::partial_sums_batch_into`]); `out` is query-major
    /// `[luts.len()][n]`.
    pub fn partial_sums_batch_into(
        &self,
        luts: &[Lut],
        k0: usize,
        k1: usize,
        out: &mut [f32],
    ) {
        match self {
            BlockedStore::U8(b) => b.partial_sums_batch_into(luts, k0, k1, out),
            BlockedStore::U16(b) => {
                b.partial_sums_batch_into(luts, k0, k1, out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;

    fn random_codes(n: usize, k: usize, m: usize, seed: u64) -> Codes {
        let mut rng = Rng::new(seed);
        let data: Vec<u16> = (0..n * k).map(|_| rng.below(m) as u16).collect();
        Codes::from_vec(n, k, data)
    }

    fn random_lut(k: usize, m: usize, seed: u64) -> Lut {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..k * m).map(|_| rng.uniform_f32()).collect();
        Lut::from_flat(k, m, data)
    }

    #[test]
    fn layout_transposes_rows_into_book_major_blocks() {
        let codes = random_codes(10, 3, 7, 1);
        let blocked = BlockedCodes::<u16>::with_block(&codes, 4);
        assert_eq!(blocked.num_blocks(), 3);
        assert_eq!(blocked.block_len(2), 2); // 10 = 4 + 4 + 2
        for i in 0..10 {
            let (b, lane) = (i / 4, i % 4);
            let blk = blocked.block(b);
            for kk in 0..3 {
                assert_eq!(blk[kk * 4 + lane], codes.get(i, kk));
                assert_eq!(blocked.get(i, kk), codes.get(i, kk));
            }
        }
        // padding lanes are code 0
        let tail = blocked.block(2);
        for kk in 0..3 {
            assert_eq!(tail[kk * 4 + 2], 0);
            assert_eq!(tail[kk * 4 + 3], 0);
        }
    }

    #[test]
    fn narrow_layout_matches_wide_layout() {
        let codes = random_codes(77, 4, 256, 2);
        let narrow = BlockedCodes::<u8>::with_block(&codes, 16);
        let wide = BlockedCodes::<u16>::with_block(&codes, 16);
        assert_eq!(narrow.num_blocks(), wide.num_blocks());
        for i in 0..77 {
            for kk in 0..4 {
                assert_eq!(narrow.get(i, kk), wide.get(i, kk));
                assert_eq!(narrow.get(i, kk), codes.get(i, kk));
            }
        }
    }

    #[test]
    fn partial_sums_match_row_major_lut_sums_both_widths() {
        let (k, m) = (5, 16);
        let lut = random_lut(k, m, 2);
        for n in [0usize, 1, 7, 64, 65, 130] {
            let codes = random_codes(n, k, m, n as u64 + 3);
            let narrow = BlockedCodes::<u8>::with_block(&codes, 64);
            let wide = BlockedCodes::<u16>::with_block(&codes, 64);
            for (k0, k1) in [(0, k), (0, 2), (2, k), (3, 3)] {
                let mut out8 = vec![f32::NAN; n];
                let mut out16 = vec![f32::NAN; n];
                narrow.partial_sums_into(&lut, k0, k1, &mut out8);
                wide.partial_sums_into(&lut, k0, k1, &mut out16);
                for i in 0..n {
                    let expect = lut.partial_sum(codes.row(i), k0, k1);
                    assert_eq!(
                        out8[i], expect,
                        "u8: n={n} i={i} books [{k0},{k1}) diverged"
                    );
                    assert_eq!(
                        out16[i], expect,
                        "u16: n={n} i={i} books [{k0},{k1}) diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn store_selects_width_by_codebook_size() {
        let codes = random_codes(20, 2, 16, 5);
        assert_eq!(BlockedStore::from_codes(&codes, 16).code_width_bits(), 8);
        assert_eq!(BlockedStore::from_codes(&codes, 256).code_width_bits(), 8);
        assert_eq!(
            BlockedStore::from_codes(&codes, 257).code_width_bits(),
            16
        );
        assert!(BlockedStore::from_codes(&codes, 256).as_u8().is_some());
        assert!(BlockedStore::from_codes(&codes, 300).as_u8().is_none());
    }

    #[test]
    fn store_sweep_matches_oracle_across_widths() {
        let (k, m) = (4, 9);
        let lut = random_lut(k, m, 7);
        let codes = random_codes(90, k, m, 8);
        for store_m in [m, 400] {
            let store = BlockedStore::from_codes(&codes, store_m);
            let mut out = vec![f32::NAN; 90];
            store.partial_sums_into(&lut, 0, k, &mut out);
            for i in 0..90 {
                assert_eq!(out[i], lut.partial_sum(codes.row(i), 0, k));
                for kk in 0..k {
                    assert_eq!(store.get(i, kk), codes.get(i, kk));
                }
            }
        }
    }

    /// The LUT-major batched sweep must be bitwise identical to running
    /// the single-query sweep once per LUT, including tail blocks and
    /// partial book ranges.
    #[test]
    fn batch_sweep_matches_serial_sweep_bitwise() {
        let (k, m) = (5, 16);
        let codes = random_codes(130, k, m, 40);
        let luts: Vec<Lut> =
            (0..7).map(|s| random_lut(k, m, 50 + s)).collect();
        for (k0, k1) in [(0usize, k), (0, 2), (1, 4)] {
            for store_m in [m, 400] {
                let store = BlockedStore::from_codes(&codes, store_m);
                let mut batch = vec![f32::NAN; luts.len() * 130];
                store.partial_sums_batch_into(&luts, k0, k1, &mut batch);
                let mut serial = vec![f32::NAN; 130];
                for (qi, lut) in luts.iter().enumerate() {
                    store.partial_sums_into(lut, k0, k1, &mut serial);
                    assert_eq!(
                        &batch[qi * 130..(qi + 1) * 130],
                        &serial[..],
                        "store_m={store_m} q={qi} books [{k0},{k1}) diverged"
                    );
                }
            }
        }
        // empty batch: nothing written, nothing read
        let store = BlockedStore::from_codes(&codes, m);
        store.partial_sums_batch_into(&[], 0, k, &mut []);
    }

    /// Range sweeps must be bitwise equal to the matching slice of the
    /// whole-database sweep, including tail blocks and empty ranges.
    #[test]
    fn range_sweep_matches_whole_sweep_slices() {
        let (k, m) = (4, 16);
        let lut = random_lut(k, m, 21);
        let codes = random_codes(150, k, m, 22);
        for store_m in [m, 400] {
            let store = BlockedStore::from_codes(&codes, store_m);
            let bs = store.block_size();
            let nb = store.num_blocks();
            let mut whole = vec![f32::NAN; 150];
            store.partial_sums_into(&lut, 0, k, &mut whole);
            for (b0, b1) in [(0usize, nb), (0, 1), (1, nb), (2, 2), (nb - 1, nb)]
            {
                let rows = store.range_rows(b0, b1);
                let mut out = vec![f32::NAN; rows];
                store.partial_sums_range_into(&lut, 0, k, b0, b1, &mut out);
                assert_eq!(
                    &out[..],
                    &whole[b0 * bs..b0 * bs + rows],
                    "store_m={store_m} blocks [{b0},{b1}) diverged"
                );
            }
        }
    }

    #[test]
    fn empty_codes_produce_no_blocks() {
        let codes = Codes::zeros(0, 4);
        let blocked = BlockedCodes::<u8>::from_codes(&codes);
        assert_eq!(blocked.num_blocks(), 0);
        assert_eq!(blocked.n(), 0);
        let lut = random_lut(4, 8, 9);
        let mut out: Vec<f32> = Vec::new();
        blocked.partial_sums_into(&lut, 0, 4, &mut out);
    }
}
