//! Block-interleaved, book-major code storage for the dense scan paths.
//!
//! The row-major [`Codes`] layout (`[n][K]` u16) is what encoders emit and
//! what the refine step wants (one vector's whole code row at a time), but
//! it is hostile to the dense crude pass: every accumulated vector strides
//! across K books, so the hardware reloads a different LUT row per add and
//! cannot vectorize the sweep. Quick ADC (André et al.) and Bolt (Blalock
//! & Guttag) fix this by transposing codes into fixed-size blocks:
//!
//! ```text
//! row-major  (Codes):        code[i][k]               i = 0..n, k = 0..K
//! blocked (BlockedCodes):    block b = [K][B] u16     b = 0..ceil(n/B)
//!                            data[(b*K + k)*B + j] = code[b*B + j][k]
//! ```
//!
//! Within a block the scan is a columnar sweep: load LUT row `k` once,
//! then add `B` contiguous code lookups into a `B`-wide accumulator —
//! a loop shape the compiler can unroll and auto-vectorize, with the LUT
//! row hot in L1 for the whole block. The tail block is padded with code
//! 0; callers copy only the first `n - b*B` lanes of the last block.
//!
//! Accumulation order per vector is books-ascending, identical to
//! [`Lut::partial_sum`] over a row-major code row, so blocked partial
//! sums are bitwise equal to the serial path — the row-major scan stays
//! around as the parity oracle (see `search_adc::search_with_lut_rowmajor`
//! and the serial `search_icq::search_with_lut`).

use super::lut::Lut;
use crate::quantizer::Codes;

/// Default vectors per block: 64 lanes keeps a whole block of codes
/// (K * 128 bytes at K = 8) plus the accumulator inside L1 while giving
/// the compiler long contiguous inner loops.
pub const DEFAULT_BLOCK: usize = 64;

/// Codes regrouped into fixed-size blocks of `B` vectors, book-major
/// (`[K][B]`) within each block. Built once at index construction from
/// the row-major [`Codes`]; immutable afterwards.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockedCodes {
    n: usize,
    k: usize,
    block: usize,
    /// `ceil(n / block)` blocks, each `[K][block]` u16; tail lanes are 0.
    data: Vec<u16>,
}

impl BlockedCodes {
    /// Transpose `codes` into blocks of [`DEFAULT_BLOCK`] vectors.
    pub fn from_codes(codes: &Codes) -> Self {
        Self::with_block(codes, DEFAULT_BLOCK)
    }

    /// Transpose `codes` into blocks of `block` vectors.
    pub fn with_block(codes: &Codes, block: usize) -> Self {
        assert!(block > 0, "block size must be >= 1");
        let (n, k) = (codes.n(), codes.k());
        let nb = n.div_ceil(block);
        let mut data = vec![0u16; nb * k * block];
        for i in 0..n {
            let (b, lane) = (i / block, i % block);
            for kk in 0..k {
                data[(b * k + kk) * block + lane] = codes.get(i, kk);
            }
        }
        BlockedCodes { n, k, block, data }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Vectors per block (B).
    #[inline]
    pub fn block_size(&self) -> usize {
        self.block
    }

    #[inline]
    pub fn num_blocks(&self) -> usize {
        self.n.div_ceil(self.block)
    }

    /// Book-major codes of block `b`: a `[K][B]` slice of length `K * B`.
    #[inline]
    pub fn block(&self, b: usize) -> &[u16] {
        let len = self.k * self.block;
        &self.data[b * len..(b + 1) * len]
    }

    /// Number of real (non-padding) lanes in block `b`.
    #[inline]
    pub fn block_len(&self, b: usize) -> usize {
        self.block.min(self.n - b * self.block)
    }

    /// Accumulate LUT partial sums over books `[k0, k1)` for block `b`
    /// into `acc[0..B]` (overwritten). Per-book LUT row is loaded once;
    /// the inner loop adds B contiguous code lookups — the
    /// auto-vectorizable sweep the module docs describe. Padding lanes
    /// accumulate code 0 and must be ignored via [`Self::block_len`].
    pub fn block_partial_sums(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        b: usize,
        acc: &mut [f32],
    ) {
        let bs = self.block;
        debug_assert_eq!(acc.len(), bs);
        let blk = self.block(b);
        acc.fill(0.0);
        for kk in k0..k1 {
            let row = lut.row(kk);
            let codes = &blk[kk * bs..(kk + 1) * bs];
            for (a, &c) in acc.iter_mut().zip(codes) {
                *a += row[c as usize];
            }
        }
    }

    /// Dense sweep over the whole database:
    /// `out[i] = sum_{k in [k0, k1)} lut[k][code[i][k]]`.
    /// This is the blocked crude pass (`k1 = fast_k`) and the blocked
    /// full-ADC distance pass (`k0 = 0, k1 = K`).
    pub fn partial_sums_into(
        &self,
        lut: &Lut,
        k0: usize,
        k1: usize,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), self.n);
        let bs = self.block;
        let mut acc = vec![0.0f32; bs];
        for b in 0..self.num_blocks() {
            self.block_partial_sums(lut, k0, k1, b, &mut acc);
            let base = b * bs;
            let take = self.block_len(b);
            out[base..base + take].copy_from_slice(&acc[..take]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;

    fn random_codes(n: usize, k: usize, m: usize, seed: u64) -> Codes {
        let mut rng = Rng::new(seed);
        let data: Vec<u16> = (0..n * k).map(|_| rng.below(m) as u16).collect();
        Codes::from_vec(n, k, data)
    }

    fn random_lut(k: usize, m: usize, seed: u64) -> Lut {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..k * m).map(|_| rng.uniform_f32()).collect();
        Lut::from_flat(k, m, data)
    }

    #[test]
    fn layout_transposes_rows_into_book_major_blocks() {
        let codes = random_codes(10, 3, 7, 1);
        let blocked = BlockedCodes::with_block(&codes, 4);
        assert_eq!(blocked.num_blocks(), 3);
        assert_eq!(blocked.block_len(2), 2); // 10 = 4 + 4 + 2
        for i in 0..10 {
            let (b, lane) = (i / 4, i % 4);
            let blk = blocked.block(b);
            for kk in 0..3 {
                assert_eq!(blk[kk * 4 + lane], codes.get(i, kk));
            }
        }
        // padding lanes are code 0
        let tail = blocked.block(2);
        for kk in 0..3 {
            assert_eq!(tail[kk * 4 + 2], 0);
            assert_eq!(tail[kk * 4 + 3], 0);
        }
    }

    #[test]
    fn partial_sums_match_row_major_lut_sums() {
        let (k, m) = (5, 16);
        let lut = random_lut(k, m, 2);
        for n in [0usize, 1, 7, 64, 65, 130] {
            let codes = random_codes(n, k, m, n as u64 + 3);
            let blocked = BlockedCodes::with_block(&codes, 64);
            for (k0, k1) in [(0, k), (0, 2), (2, k), (3, 3)] {
                let mut out = vec![f32::NAN; n];
                blocked.partial_sums_into(&lut, k0, k1, &mut out);
                for i in 0..n {
                    let expect = lut.partial_sum(codes.row(i), k0, k1);
                    assert_eq!(
                        out[i], expect,
                        "n={n} i={i} books [{k0},{k1}) diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn empty_codes_produce_no_blocks() {
        let codes = Codes::zeros(0, 4);
        let blocked = BlockedCodes::from_codes(&codes);
        assert_eq!(blocked.num_blocks(), 0);
        assert_eq!(blocked.n(), 0);
        let lut = random_lut(4, 8, 9);
        let mut out: Vec<f32> = Vec::new();
        blocked.partial_sums_into(&lut, 0, 4, &mut out);
    }
}
