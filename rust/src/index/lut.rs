//! ADC lookup tables — the rust-native mirror of the L1 Pallas kernel.
//!
//! `Lut::build` computes T[k, j] = ||q o s_k - c_{k,j}||^2 with the same
//! expansion the kernel uses (||q o s_k||^2 - 2 q.c + ||c||^2), using
//! precomputed ||c||^2 and support masks from [`LutContext`]. Numeric
//! parity with the Pallas kernel is covered by the runtime integration
//! test (HLO-executed LUT vs this implementation).

use crate::core::distance::{self, Metric};
use crate::quantizer::Codebooks;

/// Precomputed, query-independent LUT state (built once per index).
///
/// Performance note (EXPERIMENTS.md section Perf): codewords are sparse —
/// a codebook's support is |psi| or d/K-ish dims — so the cross terms are
/// computed against a COMPACT [m, |support|] copy of each book with the
/// query gathered onto the same dims. This cuts LUT-build MACs from
/// K*m*d to m*d total (each dim belongs to exactly one book for
/// group-orthogonal quantizers), a K-fold flop reduction.
#[derive(Clone, Debug)]
pub struct LutContext {
    k: usize,
    m: usize,
    d: usize,
    /// ||c_{k,j}||^2, [K, m].
    c_sq: Vec<f32>,
    /// support dims per book.
    dims: Vec<Vec<u32>>,
    /// compact codebooks, [m, |support_k|] row-major per book.
    compact: Vec<Vec<f32>>,
}

impl LutContext {
    /// Precompute the query-independent state for `codebooks`:
    /// codeword norms, support dims, and compact per-book copies.
    pub fn new(codebooks: &Codebooks) -> Self {
        let (k, m, d) = (codebooks.k(), codebooks.m(), codebooks.d());
        let mut c_sq = vec![0.0f32; k * m];
        for kk in 0..k {
            for j in 0..m {
                c_sq[kk * m + j] = distance::norm_sq(codebooks.codeword(kk, j));
            }
        }
        let mut dims = Vec::with_capacity(k);
        let mut compact = Vec::with_capacity(k);
        for kk in 0..k {
            let sup = codebooks.support_dims(kk);
            let mut book = vec![0.0f32; m * sup.len()];
            for j in 0..m {
                let cw = codebooks.codeword(kk, j);
                for (si, &dim) in sup.iter().enumerate() {
                    book[j * sup.len() + si] = cw[dim as usize];
                }
            }
            dims.push(sup);
            compact.push(book);
        }
        LutContext { k, m, d, c_sq, dims, compact }
    }

    /// Number of codebooks (K).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Codewords per book (m).
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Exact MAC count of one [`Lut::build`] call with this context:
    /// `m * sum_k |support_k|`. Equals `m * d` when book supports
    /// partition the dims (PQ/OPQ/ICQ); up to `K * m * d` for dense
    /// codebooks (CQ/SQ). The search executors charge this to the flop
    /// counters.
    pub fn build_macs(&self) -> usize {
        self.m * self.dims.iter().map(|d| d.len()).sum::<usize>()
    }
}

/// Per-query lookup table, [K, m] row-major.
#[derive(Clone, Debug)]
pub struct Lut {
    k: usize,
    m: usize,
    data: Vec<f32>,
}

impl Lut {
    /// Build for one query. Cost: m * d MACs total for group-orthogonal
    /// codebooks (each dim in exactly one book) — the compact layout in
    /// [`LutContext`] skips every off-support zero.
    pub fn build(ctx: &LutContext, _codebooks: &Codebooks, q: &[f32]) -> Lut {
        assert_eq!(q.len(), ctx.d);
        let (k, m) = (ctx.k, ctx.m);
        let mut data = vec![0.0f32; k * m];
        let mut q_sub = Vec::with_capacity(ctx.d);
        for kk in 0..k {
            let dims = &ctx.dims[kk];
            let s_len = dims.len();
            // gather the query onto this book's support
            q_sub.clear();
            let mut qsq = 0.0f32;
            for &dim in dims {
                let v = q[dim as usize];
                q_sub.push(v);
                qsq += v * v;
            }
            let book = &ctx.compact[kk];
            let out = &mut data[kk * m..(kk + 1) * m];
            for (j, o) in out.iter_mut().enumerate() {
                let cross =
                    distance::dot(&q_sub, &book[j * s_len..(j + 1) * s_len]);
                *o = qsq - 2.0 * cross + ctx.c_sq[kk * m + j];
            }
        }
        Lut { k, m, data }
    }

    /// Build for one query under `metric`.
    ///
    /// * `L2` — identical to [`Self::build`]: entries are the
    ///   support-restricted squared distances, ADC sums approximate
    ///   `||q - x̂||²` and rank ascending.
    /// * `InnerProduct` — entries are the per-book score contributions
    ///   `⟨q, c_{k,j}⟩` (the `‖x‖²` term of the L2 expansion is
    ///   dropped); ADC sums approximate `⟨q, x̂⟩` and rank *descending*.
    /// * `Cosine` — inner product with the query normalized to unit
    ///   norm first; base rows are normalized once at encode time, so
    ///   the resulting scan is bitwise the IP scan on pre-normalized
    ///   data.
    pub fn build_metric(
        ctx: &LutContext,
        codebooks: &Codebooks,
        q: &[f32],
        metric: Metric,
    ) -> Lut {
        match metric {
            Metric::L2 => Lut::build(ctx, codebooks, q),
            Metric::InnerProduct => Lut::build_ip(ctx, q),
            Metric::Cosine => {
                let mut qn = q.to_vec();
                distance::normalize(&mut qn);
                Lut::build_ip(ctx, &qn)
            }
        }
    }

    /// The inner-product table: T[k, j] = ⟨q, c_{k,j}⟩ over book k's
    /// support (codewords are zero off-support, so the restricted dot
    /// is the full one).
    fn build_ip(ctx: &LutContext, q: &[f32]) -> Lut {
        assert_eq!(q.len(), ctx.d);
        let (k, m) = (ctx.k, ctx.m);
        let mut data = vec![0.0f32; k * m];
        let mut q_sub = Vec::with_capacity(ctx.d);
        for kk in 0..k {
            let dims = &ctx.dims[kk];
            let s_len = dims.len();
            q_sub.clear();
            for &dim in dims {
                q_sub.push(q[dim as usize]);
            }
            let book = &ctx.compact[kk];
            let out = &mut data[kk * m..(kk + 1) * m];
            for (j, o) in out.iter_mut().enumerate() {
                *o = distance::dot(&q_sub, &book[j * s_len..(j + 1) * s_len]);
            }
        }
        Lut { k, m, data }
    }

    /// Build from a runtime-produced flat [K, m] table (the PJRT path).
    pub fn from_flat(k: usize, m: usize, data: Vec<f32>) -> Lut {
        assert_eq!(data.len(), k * m);
        Lut { k, m, data }
    }

    /// Upper bound on any code row's partial sum over books `[k0, k1)`:
    /// the sum of per-book row maxima. Under a similarity metric the
    /// crude pass only sums the fast group `[0, fast_k)`, and — unlike
    /// L2, whose dropped terms are non-negative — the dropped tail
    /// `[fast_k, K)` can be any sign, so this per-query constant is the
    /// slack that restores `crude + tail >= full` (the upper-bound
    /// mirror of eq. 11's pruning argument).
    pub fn tail_upper_bound(&self, k0: usize, k1: usize) -> f32 {
        let mut s = 0.0f32;
        for kk in k0..k1 {
            let row = self.row(kk);
            let mut best = f32::NEG_INFINITY;
            for &v in row {
                if v > best {
                    best = v;
                }
            }
            if best.is_finite() {
                s += best;
            }
        }
        s
    }

    /// Entry for codeword `j` of book `k`.
    #[inline]
    pub fn get(&self, k: usize, j: usize) -> f32 {
        self.data[k * self.m + j]
    }

    /// The m entries of book `k`, contiguous.
    #[inline]
    pub fn row(&self, k: usize) -> &[f32] {
        &self.data[k * self.m..(k + 1) * self.m]
    }

    /// Sum of entries for a code row over books [k0, k1).
    #[inline]
    pub fn partial_sum(&self, codes: &[u16], k0: usize, k1: usize) -> f32 {
        let mut s = 0.0;
        for kk in k0..k1 {
            s += self.data[kk * self.m + codes[kk] as usize];
        }
        s
    }

    /// Number of codebooks (K).
    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Codewords per book (m).
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Matrix, Rng};
    use crate::quantizer::{pq::Pq, pq::PqOpts, Quantizer};

    #[test]
    fn lut_entries_are_support_restricted_distances() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(100, 6, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 3, m: 4, iters: 5, seed: 0 });
        let cb = pq.codebooks();
        let ctx = LutContext::new(cb);
        let q: Vec<f32> = (0..6).map(|_| rng.normal_f32()).collect();
        let lut = Lut::build(&ctx, cb, &q);
        for kk in 0..3 {
            let sup = cb.support(kk);
            for j in 0..4 {
                let expect =
                    distance::l2_sq_masked(&q, cb.codeword(kk, j), &sup);
                assert!(
                    (lut.get(kk, j) - expect).abs() < 1e-3,
                    "lut({kk},{j}) {} expect {expect}",
                    lut.get(kk, j)
                );
            }
        }
    }

    #[test]
    fn build_macs_tracks_support_density() {
        // disjoint supports (PQ-like): m * d
        let mut rng = Rng::new(3);
        let x = Matrix::from_fn(60, 8, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 4, seed: 0 });
        let ctx = LutContext::new(pq.codebooks());
        assert_eq!(ctx.build_macs(), 8 * 8);
        // dense codebooks (CQ-like): K * m * d
        let dense = crate::quantizer::Codebooks::from_vec(
            2,
            3,
            4,
            vec![1.0; 2 * 3 * 4],
        );
        let dense_ctx = LutContext::new(&dense);
        assert_eq!(dense_ctx.build_macs(), 2 * 3 * 4);
    }

    #[test]
    fn partial_sum_matches_manual() {
        let lut = Lut::from_flat(2, 3, vec![1., 2., 3., 10., 20., 30.]);
        let codes = [2u16, 1u16];
        assert_eq!(lut.partial_sum(&codes, 0, 2), 3.0 + 20.0);
        assert_eq!(lut.partial_sum(&codes, 0, 1), 3.0);
        assert_eq!(lut.partial_sum(&codes, 1, 2), 20.0);
    }

    #[test]
    fn ip_entries_are_codeword_dots_and_cosine_normalizes() {
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(100, 6, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 3, m: 4, iters: 5, seed: 0 });
        let cb = pq.codebooks();
        let ctx = LutContext::new(cb);
        let q: Vec<f32> = (0..6).map(|_| rng.normal_f32()).collect();
        let lut = Lut::build_metric(&ctx, cb, &q, Metric::InnerProduct);
        for kk in 0..3 {
            for j in 0..4 {
                let expect = distance::dot(&q, cb.codeword(kk, j));
                assert!(
                    (lut.get(kk, j) - expect).abs() < 1e-4,
                    "ip lut({kk},{j}) {} expect {expect}",
                    lut.get(kk, j)
                );
            }
        }
        // cosine == IP on the normalized query, bitwise
        let mut qn = q.clone();
        distance::normalize(&mut qn);
        let cos = Lut::build_metric(&ctx, cb, &q, Metric::Cosine);
        let ipn = Lut::build_metric(&ctx, cb, &qn, Metric::InnerProduct);
        for kk in 0..3 {
            assert_eq!(cos.row(kk), ipn.row(kk));
        }
    }

    #[test]
    fn tail_upper_bound_dominates_every_partial_sum() {
        let lut = Lut::from_flat(3, 2, vec![1., -2., -3., 0.5, 2., -1.]);
        let ub = lut.tail_upper_bound(1, 3);
        for c1 in 0..2u16 {
            for c2 in 0..2u16 {
                let codes = [0u16, c1, c2];
                assert!(lut.partial_sum(&codes, 1, 3) <= ub);
            }
        }
        assert_eq!(lut.tail_upper_bound(3, 3), 0.0);
    }

    #[test]
    fn full_sum_equals_exact_distance_for_disjoint_supports() {
        let mut rng = Rng::new(2);
        let x = Matrix::from_fn(80, 8, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 8, seed: 0 });
        let cb = pq.codebooks();
        let codes = pq.encode(&x);
        let ctx = LutContext::new(cb);
        let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
        let lut = Lut::build(&ctx, cb, &q);
        for i in 0..10 {
            let recon = cb.reconstruct(codes.row(i));
            let exact = distance::l2_sq(&q, &recon);
            let adc = lut.partial_sum(codes.row(i), 0, 4);
            assert!((adc - exact).abs() < 1e-3, "adc {adc} exact {exact}");
        }
    }
}
