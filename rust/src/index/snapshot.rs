//! Snapshot dispatch: one typed probe classifying any index snapshot.
//!
//! An on-disk snapshot varies along two independent axes:
//!
//! * **container** — the icqfmt v1 pack (`TensorPack`, streamed and
//!   deserialized) or the icqfmt2 mapped container
//!   ([`crate::data::mapped`], validated once and adopted zero-copy);
//! * **kind** — a plain flat index, a wire shard (flat index + the
//!   `shard_start`/`shard_total` placement manifest), or an IVF index
//!   (`ivf_*` partition tensors over a cell-major base).
//!
//! Before this module each loader re-derived "what is this file?" from
//! the presence of individual tensors, and the answers could drift:
//! [`load_index`] and [`load_shard_pack`] must agree on what an IVF
//! snapshot is, or a shard server handed one would silently misnumber
//! every row id. [`SnapshotKind`] makes that decision once — the same
//! probe for both containers — and every loader matches it
//! exhaustively, so adding a snapshot kind is a compile error at each
//! dispatch site instead of a silent fall-through.
//!
//! [`load_index`]: super::ivf::load_index
//! [`load_shard_pack`]: super::shard::load_shard_pack

use std::path::Path;

use anyhow::Result;

use super::encoded::EncodedIndex;
use super::ivf::AnyIndex;
use crate::data::format::TensorPack;
use crate::data::mapped::{
    sniff_container, ContainerFormat, MappedPack,
};

/// What an index snapshot holds, independent of container format.
///
/// Classification looks only at marker-tensor *presence* (cheap on
/// both containers — a mapped probe touches only the validated
/// directory, never a payload page). `Ivf` wins over `Shard` because
/// an IVF snapshot's base tensors are cell-major: treating one as a
/// flat range shard would misnumber row ids, so the IVF marker must
/// dominate no matter what else a (corrupt or hand-built) file
/// carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotKind {
    /// A plain flat index (e.g. from `icq train`): loads anywhere.
    Flat,
    /// A wire shard: a flat index plus its placement manifest.
    Shard,
    /// An index carrying an IVF coarse partition.
    Ivf,
}

impl SnapshotKind {
    fn classify(has_ivf: bool, has_shard: bool) -> Self {
        if has_ivf {
            SnapshotKind::Ivf
        } else if has_shard {
            SnapshotKind::Shard
        } else {
            SnapshotKind::Flat
        }
    }

    /// Classify a v1 tensor pack.
    pub fn of_pack(pack: &TensorPack) -> Self {
        Self::classify(
            pack.tensors.contains_key("ivf_version"),
            pack.tensors.contains_key("shard_start"),
        )
    }

    /// Classify a mapped icqfmt2 snapshot.
    pub fn of_mapped(mp: &MappedPack) -> Self {
        Self::classify(mp.contains("ivf_version"), mp.contains("shard_start"))
    }
}

/// An opened snapshot container, either format, not yet interpreted.
#[derive(Clone, Debug)]
pub enum SnapshotFile {
    /// An icqfmt v1 pack, fully deserialized into owned tensors.
    Pack(TensorPack),
    /// An icqfmt2 container (a zero-copy mapping or an owned image).
    Mapped(MappedPack),
}

impl SnapshotFile {
    /// What the snapshot holds (same probe for both containers).
    pub fn kind(&self) -> SnapshotKind {
        match self {
            SnapshotFile::Pack(pack) => SnapshotKind::of_pack(pack),
            SnapshotFile::Mapped(mp) => SnapshotKind::of_mapped(mp),
        }
    }
}

/// Open a snapshot file in either container format, sniffed by magic.
///
/// `mmap` selects the zero-copy open for icqfmt2 files (on platforms
/// without the mapping primitive it degrades to reading the file into
/// an owned image — same validation, same layout); v1 packs ignore it
/// and always deserialize. Metadata is fully validated here; for
/// mapped files no payload page is touched.
pub fn open_snapshot(
    path: impl AsRef<Path>,
    mmap: bool,
) -> Result<SnapshotFile> {
    let path = path.as_ref();
    match sniff_container(path)? {
        ContainerFormat::MappedV2 => Ok(SnapshotFile::Mapped(if mmap {
            MappedPack::open(path)?
        } else {
            MappedPack::open_owned(path)?
        })),
        ContainerFormat::PackV1 => {
            Ok(SnapshotFile::Pack(TensorPack::load(path)?))
        }
    }
}

/// Load any index snapshot ([`super::ivf::load_index`] across both
/// containers): flat packs stay flat, IVF packs are cut into cells,
/// wire shards load as flat indexes (placement ignored in-process).
pub fn load_any(file: &SnapshotFile) -> Result<AnyIndex> {
    match file {
        SnapshotFile::Pack(pack) => super::ivf::load_index(pack),
        SnapshotFile::Mapped(mp) => super::ivf::load_index_mapped(mp),
    }
}

/// Load a snapshot as a wire shard ([`super::shard::load_shard_pack`]
/// across both containers): returns the shard index and its global
/// start row; IVF snapshots are rejected.
pub fn load_shard_snapshot(
    file: &SnapshotFile,
) -> Result<(EncodedIndex, usize)> {
    match file {
        SnapshotFile::Pack(pack) => super::shard::load_shard_pack(pack),
        SnapshotFile::Mapped(mp) => super::shard::load_shard_mapped(mp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Matrix, Rng};
    use crate::data::mapped::{save_mapped, write_mapped};
    use crate::index::ivf::{IvfBuildOpts, IvfIndex};
    use crate::index::shard::{ShardPolicy, ShardedIndex};
    use crate::quantizer::pq::{Pq, PqOpts};

    fn flat_index(n: usize, seed: u64) -> (EncodedIndex, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 8, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 3, seed: 0 });
        let labels = (0..n).map(|i| i as i32).collect();
        (EncodedIndex::build(&pq, &x, labels), x)
    }

    /// Every (kind, container) pair classifies the same way — the
    /// exhaustive dispatch this module exists to guarantee.
    #[test]
    fn kind_probe_agrees_across_containers() {
        let (idx, x) = flat_index(130, 1);
        let sharded =
            ShardedIndex::build(&idx, ShardPolicy::Count(2)).unwrap();
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 4, iters: 4, seed: 0 },
        )
        .unwrap();
        let cases = [
            (idx.to_pack(), idx.to_mapped_tensors(), SnapshotKind::Flat),
            (
                sharded.shard_pack(1),
                sharded.shard_mapped_tensors(1),
                SnapshotKind::Shard,
            ),
            (ivf.to_pack(), ivf.to_mapped_tensors(), SnapshotKind::Ivf),
        ];
        for (pack, mapped, want) in cases {
            assert_eq!(SnapshotKind::of_pack(&pack), want);
            let mp = MappedPack::from_bytes(&write_mapped(&mapped)).unwrap();
            assert_eq!(SnapshotKind::of_mapped(&mp), want);
            assert_eq!(SnapshotFile::Mapped(mp).kind(), want);
            assert_eq!(SnapshotFile::Pack(pack).kind(), want);
        }
    }

    #[test]
    fn open_snapshot_dispatches_on_magic_and_mmap_flag() {
        let dir = std::env::temp_dir().join(format!(
            "icq-snapshot-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (idx, _) = flat_index(64, 2);

        let v1 = dir.join("flat.icqf");
        idx.to_pack().save(&v1).unwrap();
        let v2 = dir.join("flat.icq2");
        save_mapped(&idx.to_mapped_tensors(), &v2).unwrap();

        for mmap in [false, true] {
            let f1 = open_snapshot(&v1, mmap).unwrap();
            assert!(matches!(f1, SnapshotFile::Pack(_)));
            let f2 = open_snapshot(&v2, mmap).unwrap();
            assert!(matches!(f2, SnapshotFile::Mapped(_)));
            // both containers load to the same index
            for f in [&f1, &f2] {
                match load_any(f).unwrap() {
                    AnyIndex::Flat(back) => {
                        assert_eq!(back.codes(), idx.codes());
                        assert_eq!(back.labels, idx.labels);
                    }
                    AnyIndex::Ivf(_) => panic!("flat opened as IVF"),
                }
                let (shard, start) = load_shard_snapshot(f).unwrap();
                assert_eq!(start, 0);
                assert_eq!(shard.len(), idx.len());
            }
        }
        // junk magic is rejected before any loader runs
        let junk = dir.join("junk.icqf");
        std::fs::write(&junk, b"not a snapshot").unwrap();
        assert!(open_snapshot(&junk, false).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// IVF snapshots refuse the shard path through the shared probe in
    /// both containers.
    #[test]
    fn ivf_snapshots_rejected_as_wire_shards() {
        let (idx, x) = flat_index(90, 3);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 3, iters: 4, seed: 0 },
        )
        .unwrap();
        let pack_file = SnapshotFile::Pack(ivf.to_pack());
        assert!(load_shard_snapshot(&pack_file).is_err());
        let mp =
            MappedPack::from_bytes(&write_mapped(&ivf.to_mapped_tensors()))
                .unwrap();
        assert!(load_shard_snapshot(&SnapshotFile::Mapped(mp)).is_err());
        // but both load fine as ordinary indexes
        assert!(matches!(
            load_any(&pack_file).unwrap(),
            AnyIndex::Ivf(_)
        ));
    }
}
