//! Operation accounting — the paper's "Average Ops" metric.
//!
//! Figures 1-3 plot precision against the average number of table-add
//! operations per database element. For baseline ADC that is exactly K
//! adds/vector; for ICQ it is |K| adds for every vector plus (K - |K|)
//! more for the vectors whose crude test passes (plus the LUT build,
//! identical across methods at equal K*m and therefore excluded, as in
//! the paper). We count these exactly in the executors rather than
//! modeling them.

use std::sync::atomic::{AtomicU64, Ordering};

/// Cumulative operation counters for one search run.
#[derive(Debug, Default)]
pub struct OpCounter {
    /// LUT-entry additions during scans (the paper's op unit).
    pub table_adds: AtomicU64,
    /// raw f32 multiply-adds (exact search / LUT builds).
    pub flops: AtomicU64,
    /// candidates whose crude test passed and were refined.
    pub refined: AtomicU64,
    /// candidates examined in total.
    pub candidates: AtomicU64,
    /// queries processed.
    pub queries: AtomicU64,
}

impl OpCounter {
    /// Fresh counters, all zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` LUT-entry additions.
    #[inline]
    pub fn add_table_adds(&self, n: u64) {
        self.table_adds.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` raw f32 multiply-adds.
    #[inline]
    pub fn add_flops(&self, n: u64) {
        self.flops.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` candidates refined past the crude test.
    #[inline]
    pub fn add_refined(&self, n: u64) {
        self.refined.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` candidates examined.
    #[inline]
    pub fn add_candidates(&self, n: u64) {
        self.candidates.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` queries processed.
    #[inline]
    pub fn add_queries(&self, n: u64) {
        self.queries.fetch_add(n, Ordering::Relaxed);
    }

    /// Average table-adds per (query, database element) — the y/x axis
    /// unit of Figs. 1-3.
    pub fn avg_ops_per_candidate(&self) -> f64 {
        let c = self.candidates.load(Ordering::Relaxed);
        if c == 0 {
            return 0.0;
        }
        self.table_adds.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Fraction of candidates that needed refinement.
    pub fn refine_rate(&self) -> f64 {
        let c = self.candidates.load(Ordering::Relaxed);
        if c == 0 {
            return 0.0;
        }
        self.refined.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// A plain-value copy of the current counter state.
    pub fn snapshot(&self) -> OpSnapshot {
        OpSnapshot {
            table_adds: self.table_adds.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            refined: self.refined.load(Ordering::Relaxed),
            candidates: self.candidates.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter.
    pub fn reset(&self) {
        self.table_adds.store(0, Ordering::Relaxed);
        self.flops.store(0, Ordering::Relaxed);
        self.refined.store(0, Ordering::Relaxed);
        self.candidates.store(0, Ordering::Relaxed);
        self.queries.store(0, Ordering::Relaxed);
    }
}

/// A plain-value copy of the counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpSnapshot {
    /// LUT-entry additions during scans (the paper's op unit).
    pub table_adds: u64,
    /// Raw f32 multiply-adds (exact search / LUT builds).
    pub flops: u64,
    /// Candidates whose crude test passed and were refined.
    pub refined: u64,
    /// Candidates examined in total.
    pub candidates: u64,
    /// Queries processed.
    pub queries: u64,
}

impl OpSnapshot {
    /// Average table-adds per (query, database element); see
    /// [`OpCounter::avg_ops_per_candidate`].
    pub fn avg_ops_per_candidate(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.table_adds as f64 / self.candidates as f64
        }
    }

    /// Fraction of candidates that needed refinement.
    pub fn refine_rate(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.refined as f64 / self.candidates as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_averages() {
        let c = OpCounter::new();
        c.add_candidates(10);
        c.add_table_adds(25);
        c.add_refined(3);
        assert_eq!(c.avg_ops_per_candidate(), 2.5);
        assert_eq!(c.refine_rate(), 0.3);
    }

    #[test]
    fn snapshot_and_reset() {
        let c = OpCounter::new();
        c.add_queries(2);
        c.add_flops(100);
        let s = c.snapshot();
        assert_eq!(s.queries, 2);
        assert_eq!(s.flops, 100);
        c.reset();
        assert_eq!(c.snapshot(), OpSnapshot::default());
    }

    #[test]
    fn zero_candidates_safe() {
        let c = OpCounter::new();
        assert_eq!(c.avg_ops_per_candidate(), 0.0);
        assert_eq!(c.refine_rate(), 0.0);
    }
}
