//! Sharding: cut one [`EncodedIndex`] into contiguous block-range
//! shards that independent workers (threads today, hosts tomorrow) can
//! scan in parallel.
//!
//! A single flat [`BlockedCodes`] store caps both dataset size and
//! single-query latency at one core's memory bandwidth. The blocked
//! layout makes the cut points obvious: blocks are already the unit the
//! dense sweeps iterate, so a shard is simply a contiguous run of
//! blocks, re-assembled as a fully independent [`EncodedIndex`] (own
//! blocked transpose, own row-major refine codes, shared codebook
//! values). Every search executor runs on a shard unchanged.
//!
//! ```text
//! flat index rows   0 ........................................... n
//! blocks (B = 64)   |b0|b1|b2|b3|b4|b5|b6|b7|b8|b9|
//! 3 shards          [ shard 0  ][ shard 1  ][ shard 2 (tail) ]
//! ShardSpec         {start:0}    {start:256} {start:512}
//! ```
//!
//! Hit ids inside a shard are shard-local rows; `spec.start` translates
//! them back to global ids (the scatter-gather layer in
//! [`crate::coordinator::gather`] does this before merging). Labels are
//! sliced per shard, so label lookups never cross the gather boundary —
//! only small top-k candidate lists do.
//!
//! [`BlockedCodes`]: super::blocked::BlockedCodes

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::encoded::EncodedIndex;
use super::snapshot::SnapshotKind;
use crate::data::format::TensorPack;
use crate::data::mapped::MappedPack;

/// One shard's contiguous global row range `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Global row id of the shard's first vector.
    pub start: usize,
    /// One past the shard's last global row id.
    pub end: usize,
}

impl ShardSpec {
    /// Vectors in the shard.
    #[inline]
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// Whether the shard holds no vectors.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// How [`ShardedIndex::build`] chooses the cut points. Both policies
/// cut on block boundaries of the parent index, so a shard's blocked
/// layout is exactly a contiguous run of the parent's blocks (no block
/// straddles two shards, and only final tail blocks are partial).
#[derive(Clone, Copy, Debug)]
pub enum ShardPolicy {
    /// Split into (up to) this many shards of near-equal block count;
    /// clamped to the number of blocks, so every shard is non-empty.
    Count(usize),
    /// Bound each shard's blocked-code storage to roughly this many
    /// bytes (at least one block per shard).
    MaxBytes(usize),
}

/// An [`EncodedIndex`] cut into contiguous shards, each an independent
/// index (`Arc`-shared so per-shard workers can own a handle).
///
/// # Examples
///
/// ```
/// use icq::core::{Matrix, Rng};
/// use icq::index::shard::{ShardPolicy, ShardedIndex};
/// use icq::index::EncodedIndex;
/// use icq::quantizer::pq::{Pq, PqOpts};
///
/// let mut rng = Rng::new(1);
/// let x = Matrix::from_fn(300, 8, |_, _| rng.normal_f32());
/// let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 3, seed: 0 });
/// let index = EncodedIndex::build(&pq, &x, vec![0; 300]);
///
/// let sharded = ShardedIndex::build(&index, ShardPolicy::Count(3)).unwrap();
/// assert_eq!(sharded.num_shards(), 3);
/// assert_eq!(sharded.len(), index.len());
/// // shards tile the row space contiguously
/// assert_eq!(sharded.spec(0).start, 0);
/// assert_eq!(sharded.spec(2).end, 300);
/// ```
#[derive(Clone, Debug)]
pub struct ShardedIndex {
    shards: Vec<Arc<EncodedIndex>>,
    specs: Vec<ShardSpec>,
}

impl ShardedIndex {
    /// Cut `index` by `policy` (block-aligned boundaries; see
    /// [`ShardPolicy`]). An empty index yields one empty shard so the
    /// serving topology stays well-formed.
    pub fn build(index: &EncodedIndex, policy: ShardPolicy) -> Result<Self> {
        let n = index.len();
        let bs = index.blocked().block_size();
        let nb = index.blocked().num_blocks();
        let blocks_per_shard = match policy {
            ShardPolicy::Count(c) => {
                ensure!(c >= 1, "shard count must be >= 1");
                nb.div_ceil(c).max(1)
            }
            ShardPolicy::MaxBytes(bytes) => {
                ensure!(bytes >= 1, "bytes per shard must be >= 1");
                let block_bytes =
                    index.k() * bs * index.blocked().code_width_bits() / 8;
                (bytes / block_bytes.max(1)).max(1)
            }
        };
        let mut cuts = vec![0usize];
        let mut b = blocks_per_shard;
        while b < nb {
            cuts.push(b * bs);
            b += blocks_per_shard;
        }
        cuts.push(n);
        Self::from_boundaries(index, &cuts)
    }

    /// Cut at explicit global row boundaries: `cuts[0] == 0`,
    /// nondecreasing, `cuts.last() == n`; each consecutive pair is one
    /// shard (a repeated boundary makes an empty shard). Interior cuts
    /// need not be block-aligned — each shard re-blocks its own rows —
    /// but [`ShardedIndex::build`] always produces aligned cuts.
    pub fn from_boundaries(
        index: &EncodedIndex,
        cuts: &[usize],
    ) -> Result<Self> {
        ensure!(cuts.len() >= 2, "need at least one shard range");
        ensure!(cuts[0] == 0, "first boundary must be 0, got {}", cuts[0]);
        let last = *cuts.last().unwrap();
        ensure!(
            last == index.len(),
            "last boundary {last} != index length {}",
            index.len()
        );
        ensure!(
            cuts.windows(2).all(|w| w[0] <= w[1]),
            "boundaries must be nondecreasing: {cuts:?}"
        );
        let mut shards = Vec::with_capacity(cuts.len() - 1);
        let mut specs = Vec::with_capacity(cuts.len() - 1);
        for w in cuts.windows(2) {
            specs.push(ShardSpec { start: w[0], end: w[1] });
            shards.push(Arc::new(index.slice(w[0], w[1])));
        }
        Ok(ShardedIndex { shards, specs })
    }

    /// Number of shards (always >= 1).
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total vectors across all shards.
    pub fn len(&self) -> usize {
        self.specs.iter().map(|s| s.len()).sum()
    }

    /// Whether the sharded database holds no vectors.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Query dimensionality (same for every shard).
    pub fn dim(&self) -> usize {
        self.shards[0].dim()
    }

    /// Shard `s` as an independent index.
    #[inline]
    pub fn shard(&self, s: usize) -> &Arc<EncodedIndex> {
        &self.shards[s]
    }

    /// Global row range of shard `s`.
    #[inline]
    pub fn spec(&self, s: usize) -> ShardSpec {
        self.specs[s]
    }

    /// All shard row ranges, in shard order.
    pub fn specs(&self) -> &[ShardSpec] {
        &self.specs
    }

    /// All shards, in shard order (parallel to [`Self::specs`]).
    pub fn shards(&self) -> &[Arc<EncodedIndex>] {
        &self.shards
    }

    /// Translate a shard-local hit id back to a global row id.
    #[inline]
    pub fn to_global(&self, s: usize, local_id: u32) -> u32 {
        self.specs[s].start as u32 + local_id
    }

    /// Serialize shard `s` as a standalone icqfmt snapshot: the shard's
    /// own [`EncodedIndex::to_pack`] tensors plus its placement manifest
    /// (`shard_start` = global row id of the shard's first vector,
    /// `shard_total` = rows in the parent index). This is what a
    /// `shard-server` process loads to serve one shard of a larger
    /// database over the wire protocol — [`load_shard_pack`] reads it
    /// back and the server adds `shard_start` to every hit id, so remote
    /// replies arrive in the parent's global id space.
    pub fn shard_pack(&self, s: usize) -> TensorPack {
        let mut pack = self.shards[s].to_pack();
        pack.insert_i32(
            "shard_start",
            vec![1],
            vec![self.specs[s].start as i32],
        );
        pack.insert_i32("shard_total", vec![1], vec![self.len() as i32]);
        pack
    }

    /// [`Self::shard_pack`] for the icqfmt2 mapped container: the
    /// shard's [`EncodedIndex::to_mapped_tensors`] set plus the same
    /// placement manifest. Written via
    /// [`crate::data::mapped::save_mapped`], a `shard-server` opens it
    /// zero-copy with [`load_shard_mapped`].
    pub fn shard_mapped_tensors(&self, s: usize) -> TensorPack {
        let mut pack = self.shards[s].to_mapped_tensors();
        pack.insert_i32(
            "shard_start",
            vec![1],
            vec![self.specs[s].start as i32],
        );
        pack.insert_i32("shard_total", vec![1], vec![self.len() as i32]);
        pack
    }
}

/// Validate a shard's placement manifest against its row count:
/// `start` defaults to 0 when absent (plain whole-index snapshots),
/// and `shard_total`, when present, must bound `[start, start + n)`.
fn check_placement(
    start: Option<i32>,
    total: Option<i32>,
    n: usize,
) -> Result<usize> {
    let start = match start {
        Some(v) => {
            ensure!(v >= 0, "negative shard_start {v}");
            v as usize
        }
        None => 0,
    };
    if let Some(total) = total {
        ensure!(
            total >= 0 && start + n <= total as usize,
            "shard rows [{start}, {}) exceed shard_total {total}",
            start + n
        );
    }
    Ok(start)
}

/// Load a shard snapshot written by [`ShardedIndex::shard_pack`]:
/// returns the shard's standalone [`EncodedIndex`] plus the global row
/// id of its first vector. Plain whole-index snapshots (no
/// `shard_start` tensor, e.g. from `icq train`) load with start 0, so
/// one loader serves both the single-host and multi-host paths.
pub fn load_shard_pack(pack: &TensorPack) -> Result<(EncodedIndex, usize)> {
    match SnapshotKind::of_pack(pack) {
        // An IVF snapshot's base tensors are cell-major, so loading it
        // as a flat range shard would silently misnumber every row id.
        // IVF serving is cell-granular and in-process (`serve` with
        // ivf.ncells > 0), not wire-sharded.
        SnapshotKind::Ivf => bail!(
            "snapshot carries an IVF coarse partition; serve it with \
             `serve` (ivf.ncells > 0), not as a wire shard"
        ),
        SnapshotKind::Flat | SnapshotKind::Shard => {}
    }
    let index = EncodedIndex::from_pack(pack)?;
    let start = check_placement(
        pack.scalar_i32("shard_start").ok(),
        pack.scalar_i32("shard_total").ok(),
        index.len(),
    )?;
    Ok((index, start))
}

/// [`load_shard_pack`] for a mapped icqfmt2 snapshot: same dispatch
/// and placement validation, but the shard's payload segments are
/// adopted zero-copy instead of deserialized.
pub fn load_shard_mapped(mp: &MappedPack) -> Result<(EncodedIndex, usize)> {
    match SnapshotKind::of_mapped(mp) {
        SnapshotKind::Ivf => bail!(
            "snapshot carries an IVF coarse partition; serve it with \
             `serve` (ivf.ncells > 0), not as a wire shard"
        ),
        SnapshotKind::Flat | SnapshotKind::Shard => {}
    }
    let index = EncodedIndex::from_mapped(mp)?;
    let start = check_placement(
        mp.scalar_i32("shard_start").ok(),
        mp.scalar_i32("shard_total").ok(),
        index.len(),
    )?;
    Ok((index, start))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Matrix, Rng};
    use crate::quantizer::pq::{Pq, PqOpts};

    fn index(n: usize, seed: u64) -> EncodedIndex {
        let mut rng = Rng::new(seed);
        let x = Matrix::from_fn(n, 8, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 3, seed: 0 });
        EncodedIndex::build(&pq, &x, (0..n).map(|i| i as i32).collect())
    }

    #[test]
    fn count_policy_tiles_block_aligned_shards() {
        // n = 330, block 64 -> 6 blocks; 3 shards of 2 blocks each
        let idx = index(330, 1);
        let bs = idx.blocked().block_size();
        let sh = ShardedIndex::build(&idx, ShardPolicy::Count(3)).unwrap();
        assert_eq!(sh.num_shards(), 3);
        assert_eq!(sh.len(), 330);
        let mut expect_start = 0;
        for s in 0..sh.num_shards() {
            let spec = sh.spec(s);
            assert_eq!(spec.start, expect_start);
            assert_eq!(spec.start % bs, 0, "unaligned shard start");
            assert_eq!(sh.shard(s).len(), spec.len());
            expect_start = spec.end;
        }
        assert_eq!(expect_start, 330);
        // shard rows and labels match the flat index
        for s in 0..sh.num_shards() {
            let spec = sh.spec(s);
            for i in 0..spec.len() {
                assert_eq!(
                    sh.shard(s).labels[i],
                    idx.labels[spec.start + i]
                );
                assert_eq!(sh.to_global(s, i as u32), (spec.start + i) as u32);
            }
        }
    }

    #[test]
    fn count_policy_clamps_to_block_count() {
        // 2 blocks cannot make 10 shards
        let idx = index(100, 2);
        let sh = ShardedIndex::build(&idx, ShardPolicy::Count(10)).unwrap();
        assert_eq!(sh.num_shards(), 2);
        assert!(sh.specs().iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn max_bytes_policy_bounds_shard_storage() {
        let idx = index(640, 3);
        let bs = idx.blocked().block_size();
        let block_bytes =
            idx.k() * bs * idx.blocked().code_width_bits() / 8;
        // room for exactly 2 blocks per shard -> 5 shards of <= 128 rows
        let sh = ShardedIndex::build(
            &idx,
            ShardPolicy::MaxBytes(2 * block_bytes),
        )
        .unwrap();
        assert_eq!(sh.num_shards(), 5);
        for spec in sh.specs() {
            assert!(spec.len() <= 2 * bs);
        }
        // tighter than one block still gives one block per shard
        let sh1 = ShardedIndex::build(&idx, ShardPolicy::MaxBytes(1)).unwrap();
        assert_eq!(sh1.num_shards(), idx.blocked().num_blocks());
    }

    #[test]
    fn explicit_boundaries_allow_empty_and_unaligned_shards() {
        let idx = index(130, 4);
        let sh =
            ShardedIndex::from_boundaries(&idx, &[0, 0, 65, 65, 130]).unwrap();
        assert_eq!(sh.num_shards(), 4);
        assert!(sh.spec(0).is_empty());
        assert!(sh.spec(2).is_empty());
        assert_eq!(sh.shard(1).len(), 65);
        assert_eq!(sh.len(), 130);
    }

    #[test]
    fn rejects_malformed_boundaries() {
        let idx = index(50, 5);
        assert!(ShardedIndex::from_boundaries(&idx, &[0]).is_err());
        assert!(ShardedIndex::from_boundaries(&idx, &[1, 50]).is_err());
        assert!(ShardedIndex::from_boundaries(&idx, &[0, 40]).is_err());
        assert!(ShardedIndex::from_boundaries(&idx, &[0, 30, 20, 50]).is_err());
        assert!(ShardedIndex::build(&idx, ShardPolicy::Count(0)).is_err());
        assert!(ShardedIndex::build(&idx, ShardPolicy::MaxBytes(0)).is_err());
    }

    /// Shard snapshots must round-trip (codes, labels, search params,
    /// placement) and plain index packs must load with start 0.
    #[test]
    fn shard_pack_roundtrips_with_placement() {
        let idx = index(330, 7);
        let sh = ShardedIndex::build(&idx, ShardPolicy::Count(3)).unwrap();
        for s in 0..sh.num_shards() {
            let pack = sh.shard_pack(s);
            let (back, start) = load_shard_pack(&pack).unwrap();
            assert_eq!(start, sh.spec(s).start);
            assert_eq!(back.len(), sh.shard(s).len());
            assert_eq!(back.codes(), sh.shard(s).codes());
            assert_eq!(back.labels, sh.shard(s).labels);
            assert_eq!(back.fast_k, idx.fast_k);
            assert_eq!(back.sigma, idx.sigma);
        }
        // a plain whole-index snapshot has no placement: start 0
        let (whole, start) = load_shard_pack(&idx.to_pack()).unwrap();
        assert_eq!(start, 0);
        assert_eq!(whole.len(), idx.len());
        // corrupt placement is rejected
        let mut bad = sh.shard_pack(1);
        bad.insert_i32("shard_start", vec![1], vec![-3]);
        assert!(load_shard_pack(&bad).is_err());
        let mut bad = sh.shard_pack(2);
        bad.insert_i32("shard_total", vec![1], vec![10]);
        assert!(load_shard_pack(&bad).is_err());
    }

    /// The mapped shard snapshot carries the same placement manifest
    /// and payload as the v1 pack, adopts the code pages zero-copy,
    /// and refuses IVF snapshots exactly like the pack loader.
    #[test]
    fn mapped_shard_roundtrips_with_placement() {
        let idx = index(330, 8);
        let sh = ShardedIndex::build(&idx, ShardPolicy::Count(3)).unwrap();
        for s in 0..sh.num_shards() {
            let bytes =
                crate::data::mapped::write_mapped(&sh.shard_mapped_tensors(s));
            let mp = MappedPack::from_bytes(&bytes).unwrap();
            let (back, start) = load_shard_mapped(&mp).unwrap();
            assert_eq!(start, sh.spec(s).start);
            assert_eq!(back.codes(), sh.shard(s).codes());
            assert_eq!(back.labels, sh.shard(s).labels);
            assert!(back.labels.is_mapped());
            assert!(back.blocked().is_mapped());
        }
        // a plain mapped whole-index snapshot loads with start 0
        let bytes = crate::data::mapped::write_mapped(&idx.to_mapped_tensors());
        let (whole, start) =
            load_shard_mapped(&MappedPack::from_bytes(&bytes).unwrap())
                .unwrap();
        assert_eq!(start, 0);
        assert_eq!(whole.len(), idx.len());
        // corrupt placement is rejected
        let mut bad = sh.shard_mapped_tensors(1);
        bad.insert_i32("shard_start", vec![1], vec![-3]);
        let bytes = crate::data::mapped::write_mapped(&bad);
        assert!(
            load_shard_mapped(&MappedPack::from_bytes(&bytes).unwrap())
                .is_err()
        );
        let mut bad = sh.shard_mapped_tensors(2);
        bad.insert_i32("shard_total", vec![1], vec![10]);
        let bytes = crate::data::mapped::write_mapped(&bad);
        assert!(
            load_shard_mapped(&MappedPack::from_bytes(&bytes).unwrap())
                .is_err()
        );
        // IVF snapshots are not wire shards, mapped or not
        let x = crate::core::Matrix::from_fn(60, 8, |i, j| {
            (i * 8 + j) as f32 * 0.01
        });
        let pq = crate::quantizer::pq::Pq::train(
            &x,
            crate::quantizer::pq::PqOpts { k: 4, m: 8, iters: 3, seed: 0 },
        );
        let flat = EncodedIndex::build(&pq, &x, vec![0; 60]);
        let ivf = crate::index::ivf::IvfIndex::partition(
            &flat,
            &x,
            crate::index::ivf::IvfBuildOpts { ncells: 3, iters: 4, seed: 0 },
        )
        .unwrap();
        let bytes = crate::data::mapped::write_mapped(&ivf.to_mapped_tensors());
        assert!(
            load_shard_mapped(&MappedPack::from_bytes(&bytes).unwrap())
                .is_err()
        );
    }

    #[test]
    fn empty_index_yields_one_empty_shard() {
        let idx = index(30, 6).slice(0, 0);
        let sh = ShardedIndex::build(&idx, ShardPolicy::Count(4)).unwrap();
        assert_eq!(sh.num_shards(), 1);
        assert!(sh.is_empty());
        assert_eq!(sh.dim(), 8);
    }
}
