//! IVF coarse partition over an encoded index — non-exhaustive search.
//!
//! A k-means coarse quantizer splits the database into `ncells` cells;
//! each cell is a standalone [`EncodedIndex`] (its own block-interleaved
//! store) over the cell's member rows, with codebooks and the LUT
//! context `Arc`-shared across cells, plus a cell-local -> global row-id
//! map. A query ranks all centroids, probes the `nprobe` nearest cells
//! with the existing QLut crude sweep + two-step refine, remaps hits to
//! global ids and merges per-cell top-k lists through the canonical
//! [`merge_topk_metric`]. The `qlut <= crude <= full` lower-bound chain holds
//! unchanged *within* each probed cell — IVF only restricts *which*
//! rows are scanned, never how a scanned row is compared.
//!
//! Two build modes:
//!
//! * **partition** ([`IvfIndex::partition`]) — regroups the rows of an
//!   already-encoded flat index into cells without re-encoding. Every
//!   row keeps the exact codes the flat scan uses, per-cell id lists
//!   are ascending, and [`merge_topk_metric`] applies the same canonical
//!   `(distance, id)` order as the flat executors — so `nprobe =
//!   ncells` is **bitwise identical** to the exhaustive flat path
//!   (asserted in `tests/ivf_parity.rs`).
//! * **residual** ([`IvfIndex::build_residual`]) — re-encodes each row
//!   as `x - centroid(cell(x))`, the IVFADC construction: per-cell
//!   quantization error shrinks because the quantizer only has to
//!   cover the residual ball, at the cost of one LUT build per probed
//!   cell (the LUT argument is the query residual `q - centroid`,
//!   which differs per cell). Residual codes differ from flat codes,
//!   so this mode trades the bitwise-parity guarantee for recall.
//!
//! For serving, [`IvfIndex::split_cells`] deals whole cells round-robin
//! across shard-local sub-indexes: every shard keeps the full (cheap,
//! `Arc`-shared) centroid table so it ranks cells globally and scans
//! the probed cells it owns; because hits already carry global ids and
//! k-smallest selection is associative, the scatter-gather merge of
//! shard results equals the single-process IVF result exactly.
//!
//! Snapshots extend the flat icqfmt layout (the base tensors are the
//! cell-major concatenation of all cells, loadable by the same
//! validation path) with `ivf_*` tensors; packs without them are plain
//! flat indexes, so pre-IVF snapshots keep loading ([`load_index`]).

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use super::encoded::{blocked_from_mapped, blocked_to_tensors, EncodedIndex};
use super::lut::Lut;
use super::opcount::OpCounter;
use super::search_icq::{self, IcqSearchOpts};
use crate::core::parallel::par_map_indexed;
use crate::core::{
    distance, merge_topk_metric, Hit, Matrix, Metric, TopK,
};
use crate::data::format::{Tensor, TensorPack};
use crate::data::mapped::{CowSlice, MappedPack};
use crate::quantizer::kmeans::{self, KMeansOpts};
use crate::quantizer::{Codes, Quantizer};

/// Snapshot format version written by [`IvfIndex::to_pack`]; bumped on
/// incompatible layout changes so old binaries fail loudly instead of
/// misreading.
const IVF_VERSION: i32 = 1;

/// Coarse-quantizer training options.
#[derive(Clone, Copy, Debug)]
pub struct IvfBuildOpts {
    /// Number of coarse cells (k-means centroids). Clamped to the
    /// database size by the trainer.
    pub ncells: usize,
    /// Lloyd iterations for the coarse k-means.
    pub iters: usize,
    /// Deterministic seed (thread the config seed through so builds
    /// are reproducible).
    pub seed: u64,
}

impl Default for IvfBuildOpts {
    fn default() -> Self {
        IvfBuildOpts { ncells: 64, iters: 15, seed: 0 }
    }
}

/// One coarse cell: the cell's rows as a standalone block-interleaved
/// [`EncodedIndex`] plus the map from cell-local row to global row id.
#[derive(Clone, Debug)]
pub struct IvfCell {
    /// Cell rows as a full index (codebooks/LUT context `Arc`-shared
    /// with every other cell); hit ids are cell-local.
    pub index: Arc<EncodedIndex>,
    /// Global row id per cell-local row, strictly ascending — the
    /// invariant that keeps the canonical `(distance, id)` tie-break
    /// identical to the flat scan's.
    pub ids: Arc<Vec<u32>>,
}

/// An IVF-partitioned index: coarse centroids + per-cell code lists.
///
/// A "flat" IVF index owns every cell; [`IvfIndex::split_cells`]
/// produces shard views that own a subset (non-owned slots are `None`)
/// but share the centroid table, so all shards agree on probe ranking.
#[derive(Clone, Debug)]
pub struct IvfIndex {
    /// `[ncells, d]` coarse centroids (shared across shard views).
    centroids: Arc<Matrix>,
    /// Cell `c`'s codes + id map; `None` when another shard owns it.
    cells: Vec<Option<IvfCell>>,
    /// Residual mode: cells store codes of `x - centroid(x)` and each
    /// probed cell needs its own `q - centroid` LUT.
    residual: bool,
    /// Rows across *all* cells (the database size).
    n_total: usize,
    /// Rows across the cells this view owns (== `n_total` when flat).
    n_owned: usize,
}

impl IvfIndex {
    /// Partition an existing flat index into `opts.ncells` coarse
    /// cells *without re-encoding*: k-means over `x` (the same vectors
    /// `index` encodes, row-aligned), then each cell is
    /// [`EncodedIndex::select`] of its member rows in ascending global
    /// order. Because every row keeps its flat codes and the per-cell
    /// id maps are monotone, searching with `nprobe = ncells` is
    /// bitwise identical to the flat exhaustive scan.
    pub fn partition(
        index: &EncodedIndex,
        x: &Matrix,
        opts: IvfBuildOpts,
    ) -> Result<Self> {
        ensure!(opts.ncells >= 1, "ivf: ncells must be >= 1");
        ensure!(!index.is_empty(), "ivf: cannot partition an empty index");
        ensure!(
            x.rows() == index.len(),
            "ivf: training rows ({}) != index rows ({})",
            x.rows(),
            index.len()
        );
        ensure!(
            x.cols() == index.dim(),
            "ivf: training dim ({}) != index dim ({})",
            x.cols(),
            index.dim()
        );
        let km = kmeans::train(
            x,
            KMeansOpts { m: opts.ncells, iters: opts.iters, seed: opts.seed },
            None,
        );
        let ncells = km.centroids.rows();
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); ncells];
        // ascending global order per cell: the parity invariant
        for (i, &c) in km.assignment.iter().enumerate() {
            members[c as usize].push(i as u32);
        }
        let cells = members
            .into_iter()
            .map(|ids| {
                let cell = index.select(&ids);
                Some(IvfCell {
                    index: Arc::new(cell),
                    ids: Arc::new(ids),
                })
            })
            .collect();
        Ok(IvfIndex {
            centroids: Arc::new(km.centroids),
            cells,
            residual: false,
            n_total: index.len(),
            n_owned: index.len(),
        })
    }

    /// Build an IVFADC-style residual index: k-means over `x` for the
    /// coarse cells, then each cell encodes its rows' residuals
    /// `x - centroid(cell)` with `quantizer` (already trained — on
    /// residuals for best quality, though any codebooks in the common
    /// layout work). `fast_k`/`sigma` wire the two-step search
    /// parameters exactly as [`EncodedIndex::build_icq`] does; pass
    /// `(K, 0.0)` for plain-ADC methods. Cells share one `Arc`'d
    /// codebook set and LUT context.
    pub fn build_residual<Q: Quantizer>(
        quantizer: &Q,
        x: &Matrix,
        labels: &[i32],
        fast_k: usize,
        sigma: f32,
        opts: IvfBuildOpts,
    ) -> Result<Self> {
        ensure!(opts.ncells >= 1, "ivf: ncells must be >= 1");
        ensure!(x.rows() > 0, "ivf: cannot build over an empty database");
        ensure!(
            x.rows() == labels.len(),
            "ivf: labels length ({}) != rows ({})",
            labels.len(),
            x.rows()
        );
        let codebooks = quantizer.codebooks().clone();
        ensure!(
            x.cols() == codebooks.d(),
            "ivf: data dim ({}) != codebook dim ({})",
            x.cols(),
            codebooks.d()
        );
        ensure!(
            fast_k >= 1 && fast_k <= codebooks.k(),
            "ivf: fast_k {fast_k} out of [1, {}]",
            codebooks.k()
        );
        let km = kmeans::train(
            x,
            KMeansOpts { m: opts.ncells, iters: opts.iters, seed: opts.seed },
            None,
        );
        let ncells = km.centroids.rows();
        let d = x.cols();
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); ncells];
        for (i, &c) in km.assignment.iter().enumerate() {
            members[c as usize].push(i as u32);
        }
        let codebooks = Arc::new(codebooks);
        let lut_ctx =
            Arc::new(super::lut::LutContext::new(codebooks.as_ref()));
        let cells = members
            .into_iter()
            .enumerate()
            .map(|(c, ids)| {
                let cent = km.centroids.row(c);
                let mut resid = Matrix::zeros(ids.len(), d);
                let mut cell_labels = Vec::with_capacity(ids.len());
                for (li, &g) in ids.iter().enumerate() {
                    let row = x.row(g as usize);
                    let out = resid.row_mut(li);
                    for j in 0..d {
                        out[j] = row[j] - cent[j];
                    }
                    cell_labels.push(labels[g as usize]);
                }
                let codes = quantizer.encode(&resid);
                // residual decomposition is an L2 identity
                // (see the metric() doc); cells are always L2
                let cell = EncodedIndex::assemble_shared(
                    codebooks.clone(),
                    lut_ctx.clone(),
                    codes,
                    fast_k,
                    sigma,
                    Metric::L2,
                    cell_labels.into(),
                );
                Some(IvfCell {
                    index: Arc::new(cell),
                    ids: Arc::new(ids),
                })
            })
            .collect();
        Ok(IvfIndex {
            centroids: Arc::new(km.centroids),
            cells,
            residual: true,
            n_total: x.rows(),
            n_owned: x.rows(),
        })
    }

    /// Number of coarse cells (owned or not).
    pub fn ncells(&self) -> usize {
        self.cells.len()
    }

    /// Cells this view owns (== [`Self::ncells`] for a flat index).
    pub fn num_owned_cells(&self) -> usize {
        self.cells.iter().flatten().count()
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.centroids.cols()
    }

    /// Rows held by this view (a shard view owns a subset).
    pub fn len(&self) -> usize {
        self.n_owned
    }

    /// Whether this view holds no rows.
    pub fn is_empty(&self) -> bool {
        self.n_owned == 0
    }

    /// Database size across all cells (same for every shard view).
    pub fn n_total(&self) -> usize {
        self.n_total
    }

    /// Whether cells store residual codes (`x - centroid`).
    pub fn residual(&self) -> bool {
        self.residual
    }

    /// The metric every owned cell serves (cells inherit it from the
    /// partitioned flat index; a cell-less shard view reports L2).
    /// Residual mode is L2-only — `‖q - x‖² = ‖(q - c) - r‖²` is an L2
    /// identity with no inner-product analogue — enforced at snapshot
    /// load and at build wiring, so cells never disagree.
    pub fn metric(&self) -> Metric {
        self.cells
            .iter()
            .flatten()
            .next()
            .map_or(Metric::L2, |cell| cell.index.metric)
    }

    /// The `[ncells, d]` coarse centroid table.
    pub fn centroids(&self) -> &Matrix {
        &self.centroids
    }

    /// Cell `c` if this view owns it.
    pub fn cell(&self, c: usize) -> Option<&IvfCell> {
        self.cells[c].as_ref()
    }

    /// Rank all centroids by L2 distance to `q` and return the
    /// `min(nprobe, ncells)` nearest cell ids, nearest first (ties by
    /// cell id, via the canonical [`TopK`] order). Centroid ranking is
    /// L2 for every metric: at `nprobe = ncells` the order is
    /// irrelevant (all cells scanned — the parity anchor), and for
    /// partial probes nearest-centroid is the standard recall
    /// heuristic (exact for cosine over normalized data, approximate
    /// for raw inner product).
    pub fn probe_order(&self, q: &[f32], nprobe: usize) -> Vec<u32> {
        let ncells = self.ncells();
        let mut top = TopK::new(nprobe.clamp(1, ncells.max(1)));
        for c in 0..ncells {
            top.push(c as u32, distance::l2_sq(q, self.centroids.row(c)));
        }
        top.into_sorted().iter().map(|h| h.id).collect()
    }

    /// Search the `nprobe` nearest owned cells and merge to global
    /// top-`opts.k` hits (ids are global row ids; labels come from the
    /// cells). `nprobe >= ncells` probes everything — bitwise equal to
    /// the flat exhaustive scan in partition mode.
    pub fn search(
        &self,
        q: &[f32],
        nprobe: usize,
        opts: IcqSearchOpts,
        ops: &OpCounter,
    ) -> Vec<Hit> {
        self.search_scratch(q, nprobe, opts, ops, &mut Vec::new())
    }

    /// [`Self::search`] with a caller-owned crude-distance scratch
    /// buffer (reused across queries on a hot path).
    ///
    /// Operation accounting: centroid ranking charges `ncells * d`
    /// MACs as flops; each probed cell then accounts exactly like a
    /// flat scan of that cell (so the per-cell sweeps each bump the
    /// query counter — per-query executor invocations, not end-user
    /// queries).
    pub fn search_scratch(
        &self,
        q: &[f32],
        nprobe: usize,
        opts: IcqSearchOpts,
        ops: &OpCounter,
        crude: &mut Vec<f32>,
    ) -> Vec<Hit> {
        let probes = self.probe_order(q, nprobe);
        ops.add_flops((self.ncells() * self.dim()) as u64);
        let mut shared: Option<Lut> = None;
        let mut lists: Vec<Vec<Hit>> = Vec::with_capacity(probes.len());
        for &c in &probes {
            let cell = match &self.cells[c as usize] {
                Some(cell) if !cell.index.is_empty() => cell,
                _ => continue,
            };
            let hits = if self.residual {
                // per-cell LUT over the query residual q - centroid
                let cent = self.centroids.row(c as usize);
                let rq: Vec<f32> =
                    q.iter().zip(cent).map(|(qv, cv)| qv - cv).collect();
                let lut = Lut::build(
                    cell.index.lut_ctx(),
                    cell.index.codebooks(),
                    &rq,
                );
                ops.add_flops(cell.index.lut_ctx().build_macs() as u64);
                search_icq::search_scanfirst_qlut(
                    &cell.index,
                    &lut,
                    opts,
                    ops,
                    crude,
                )
            } else {
                // partition mode: one LUT serves every cell (same
                // codebooks, codes unchanged from the flat index)
                if shared.is_none() {
                    shared = Some(Lut::build_metric(
                        cell.index.lut_ctx(),
                        cell.index.codebooks(),
                        q,
                        cell.index.metric,
                    ));
                    ops.add_flops(cell.index.lut_ctx().build_macs() as u64);
                }
                search_icq::search_scanfirst_qlut(
                    &cell.index,
                    shared.as_ref().expect("lut built above"),
                    opts,
                    ops,
                    crude,
                )
            };
            lists.push(
                hits.into_iter()
                    .map(|h| Hit {
                        id: cell.ids[h.id as usize],
                        dist: h.dist,
                    })
                    .collect(),
            );
        }
        merge_topk_metric(&lists, opts.k, self.metric())
    }

    /// Batched [`Self::search`], rayon-parallel over queries.
    pub fn search_batch(
        &self,
        queries: &Matrix,
        nprobe: usize,
        opts: IcqSearchOpts,
        ops: &OpCounter,
    ) -> Vec<Vec<Hit>> {
        par_map_indexed(queries.rows(), |i| {
            self.search(queries.row(i), nprobe, opts, ops)
        })
    }

    /// Deal owned cells round-robin (`cell_id % n_shards`) into
    /// `n_shards` shard views. Every view shares the centroid table
    /// (so probe ranking is global) and the dealt cells' `Arc`s; the
    /// merge of all shard results equals this index's result exactly,
    /// because hits carry global ids and k-smallest selection under
    /// the canonical order is associative.
    pub fn split_cells(&self, n_shards: usize) -> Result<Vec<IvfIndex>> {
        ensure!(n_shards >= 1, "ivf: n_shards must be >= 1");
        let n_shards = n_shards.min(self.ncells());
        let mut shards: Vec<IvfIndex> = (0..n_shards)
            .map(|_| IvfIndex {
                centroids: self.centroids.clone(),
                cells: vec![None; self.ncells()],
                residual: self.residual,
                n_total: self.n_total,
                n_owned: 0,
            })
            .collect();
        for (c, cell) in self.cells.iter().enumerate() {
            if let Some(cell) = cell {
                let s = c % n_shards;
                shards[s].n_owned += cell.index.len();
                shards[s].cells[c] = Some(cell.clone());
            }
        }
        Ok(shards)
    }

    /// Serialize to an icqfmt pack. The base tensors (`codes`,
    /// `labels`, ...) hold the cell-major concatenation of all cells —
    /// the exact layout [`EncodedIndex::from_pack`] validates — plus
    /// `ivf_version`, `ivf_centroids`, `ivf_residual`,
    /// `ivf_cell_sizes` and `ivf_row_global` describing the partition.
    /// Only whole (un-split) indexes snapshot; shard views are an
    /// in-process serving construct.
    pub fn to_pack(&self) -> TensorPack {
        assert!(
            self.cells.iter().all(Option::is_some),
            "ivf: only a whole IVF index snapshots; shard views do not"
        );
        let first = self.cells[0].as_ref().expect("checked above");
        let codebooks = first.index.codebooks();
        let (k, d) = (codebooks.k(), codebooks.d());
        let (fast_k, sigma) = (first.index.fast_k, first.index.sigma);
        let ncells = self.ncells();

        let mut codes = Vec::with_capacity(self.n_total * k);
        let mut labels = Vec::with_capacity(self.n_total);
        let mut globals = Vec::with_capacity(self.n_total);
        let mut sizes = Vec::with_capacity(ncells);
        for cell in self.cells.iter().flatten() {
            codes.extend(
                cell.index.codes().as_slice().iter().map(|&c| c as i32),
            );
            labels.extend_from_slice(&cell.index.labels);
            globals.extend(cell.ids.iter().map(|&g| g as i32));
            sizes.push(cell.index.len() as i32);
        }

        let mut pack = TensorPack::new();
        codebooks.to_pack(&mut pack, "");
        pack.insert_i32("codes", vec![self.n_total, k], codes);
        pack.insert_i32("fast_k", vec![1], vec![fast_k as i32]);
        pack.insert_f32("sigma", vec![1], vec![sigma]);
        pack.insert_i32("metric", vec![1], vec![self.metric().as_i32()]);
        pack.insert_i32("labels", vec![self.n_total], labels);
        pack.insert_i32("ivf_version", vec![1], vec![IVF_VERSION]);
        pack.insert_f32(
            "ivf_centroids",
            vec![ncells, d],
            self.centroids.as_slice().to_vec(),
        );
        pack.insert_i32(
            "ivf_residual",
            vec![1],
            vec![i32::from(self.residual)],
        );
        pack.insert_i32("ivf_cell_sizes", vec![ncells], sizes);
        pack.insert_i32("ivf_row_global", vec![self.n_total], globals);
        pack
    }

    /// Serialize to the tensor set the icqfmt2 mapped container stores
    /// for an IVF index: the flat base tensors in cell-major order
    /// (u16 codes + labels, sliced per cell zero-copy at open), the
    /// `ivf_*` partition tensors of [`Self::to_pack`], and one
    /// block-major transpose per non-empty cell under
    /// `ivf_cell{c:05}.blocked_*` — cell boundaries are not
    /// block-aligned, so cells cannot share one transpose the way they
    /// share the row-major code table.
    pub fn to_mapped_tensors(&self) -> TensorPack {
        assert!(
            self.cells.iter().all(Option::is_some),
            "ivf: only a whole IVF index snapshots; shard views do not"
        );
        let first = self.cells[0].as_ref().expect("checked above");
        let codebooks = first.index.codebooks();
        let (k, d) = (codebooks.k(), codebooks.d());
        let (fast_k, sigma) = (first.index.fast_k, first.index.sigma);
        let ncells = self.ncells();

        let mut pack = TensorPack::new();
        let mut codes = Vec::with_capacity(self.n_total * k);
        let mut labels = Vec::with_capacity(self.n_total);
        let mut globals = Vec::with_capacity(self.n_total);
        let mut sizes = Vec::with_capacity(ncells);
        for (c, cell) in self.cells.iter().flatten().enumerate() {
            codes.extend_from_slice(cell.index.codes().as_slice());
            labels.extend_from_slice(&cell.index.labels);
            globals.extend(cell.ids.iter().map(|&g| g as i32));
            sizes.push(cell.index.len() as i32);
            if !cell.index.is_empty() {
                blocked_to_tensors(
                    cell.index.blocked(),
                    &mut pack,
                    &format!("ivf_cell{c:05}."),
                );
            }
        }

        codebooks.to_pack(&mut pack, "");
        pack.tensors.insert(
            "codes".into(),
            Tensor::U16 { dims: vec![self.n_total, k], data: codes },
        );
        pack.insert_i32("fast_k", vec![1], vec![fast_k as i32]);
        pack.insert_f32("sigma", vec![1], vec![sigma]);
        pack.insert_i32("metric", vec![1], vec![self.metric().as_i32()]);
        pack.insert_i32("labels", vec![self.n_total], labels);
        pack.insert_i32(
            "blocked_width",
            vec![1],
            vec![first.index.blocked().code_width_bits() as i32],
        );
        pack.insert_i32(
            "blocked_block",
            vec![1],
            vec![first.index.blocked().block_size() as i32],
        );
        pack.insert_i32("ivf_version", vec![1], vec![IVF_VERSION]);
        pack.insert_f32(
            "ivf_centroids",
            vec![ncells, d],
            self.centroids.as_slice().to_vec(),
        );
        pack.insert_i32(
            "ivf_residual",
            vec![1],
            vec![i32::from(self.residual)],
        );
        pack.insert_i32("ivf_cell_sizes", vec![ncells], sizes);
        pack.insert_i32("ivf_row_global", vec![self.n_total], globals);
        pack
    }

    /// Load a snapshot written by [`Self::to_pack`]. The base index is
    /// validated by [`EncodedIndex::from_pack`]; the partition tensors
    /// are then checked for internal consistency (sizes sum to `n`,
    /// global ids a permutation of `0..n`, ascending within each cell
    /// — the parity invariant) before cells are cut out of the flat
    /// cell-major store with [`EncodedIndex::slice`], `Arc`-sharing
    /// the codebooks and LUT context.
    pub fn from_pack(pack: &TensorPack) -> Result<Self> {
        let version = pack.scalar_i32("ivf_version")?;
        ensure!(
            version == IVF_VERSION,
            "unsupported ivf_version {version} (this build reads {IVF_VERSION})"
        );
        let flat = EncodedIndex::from_pack(pack)?;
        let n = flat.len();

        let (cdims, cents) = pack.f32("ivf_centroids")?;
        ensure!(
            cdims.len() == 2 && cdims[0] >= 1,
            "ivf_centroids must be [ncells >= 1, d]"
        );
        let (ncells, d) = (cdims[0], cdims[1]);
        ensure!(
            d == flat.dim(),
            "ivf_centroids dim {d} != codebook dim {}",
            flat.dim()
        );
        let residual = match pack.scalar_i32("ivf_residual")? {
            0 => false,
            1 => true,
            other => bail!("ivf_residual must be 0 or 1, got {other}"),
        };
        ensure!(
            !residual || flat.metric == Metric::L2,
            "ivf residual snapshots are L2-only; this one is tagged {}",
            flat.metric
        );

        let (sdims, sizes) = pack.i32("ivf_cell_sizes")?;
        ensure!(
            sdims.len() == 1 && sdims[0] == ncells,
            "ivf_cell_sizes must be [ncells]"
        );
        let mut total = 0usize;
        for &s in sizes {
            ensure!(s >= 0, "ivf_cell_sizes holds a negative size {s}");
            total += s as usize;
        }
        ensure!(
            total == n,
            "ivf_cell_sizes sum to {total} but the index holds {n} rows"
        );

        let (gdims, globals) = pack.i32("ivf_row_global")?;
        ensure!(
            gdims.len() == 1 && gdims[0] == n,
            "ivf_row_global must be [n]"
        );
        let mut seen = vec![false; n];
        for &g in globals {
            ensure!(
                g >= 0 && (g as usize) < n,
                "ivf_row_global id {g} out of [0, {n})"
            );
            ensure!(!seen[g as usize], "duplicate global row id {g}");
            seen[g as usize] = true;
        }

        let mut cells = Vec::with_capacity(ncells);
        let mut off = 0usize;
        for &sz in sizes {
            let sz = sz as usize;
            let ids: Vec<u32> =
                globals[off..off + sz].iter().map(|&g| g as u32).collect();
            ensure!(
                ids.windows(2).all(|w| w[0] < w[1]),
                "cell row ids must be strictly ascending (parity invariant)"
            );
            let cell = flat.slice(off, off + sz);
            cells.push(Some(IvfCell {
                index: Arc::new(cell),
                ids: Arc::new(ids),
            }));
            off += sz;
        }
        let centroids = Matrix::from_vec(ncells, d, cents.to_vec());
        Ok(IvfIndex {
            centroids: Arc::new(centroids),
            cells,
            residual,
            n_total: n,
            n_owned: n,
        })
    }

    /// Open an IVF snapshot written by [`Self::to_mapped_tensors`].
    /// The partition tensors get the same internal-consistency checks
    /// as [`Self::from_pack`] (sizes sum to `n`, global ids a
    /// permutation of `0..n`, ascending within each cell — the parity
    /// invariant); the small metadata (centroids, per-cell id maps) is
    /// copied, while each cell's row-major codes and labels become
    /// zero-copy sub-slices of the file's cell-major tensors and its
    /// block-major transpose is adopted in place from the cell's own
    /// `ivf_cell*.blocked_*` segment.
    pub fn from_mapped(mp: &MappedPack) -> Result<Self> {
        let version = mp.scalar_i32("ivf_version")?;
        ensure!(
            version == IVF_VERSION,
            "unsupported ivf_version {version} (this build reads {IVF_VERSION})"
        );
        let (codebooks, lut_ctx) = EncodedIndex::codebooks_from_mapped(mp)?;
        let (k, m) = (codebooks.k(), codebooks.m());
        let (cdims, codes_seg) = mp.segment::<u16>("codes")?;
        ensure!(
            cdims.len() == 2 && cdims[1] == k,
            "codes must be [n, K={k}], got {cdims:?}"
        );
        let n = cdims[0];
        let (ldims, labels_seg) = mp.segment::<i32>("labels")?;
        ensure!(
            ldims == [n].as_slice(),
            "labels must be [n={n}], got {ldims:?}"
        );
        let fast_k = mp.scalar_i32("fast_k")?;
        ensure!(
            fast_k >= 1 && fast_k as usize <= k,
            "fast_k={fast_k} outside [1, K={k}]"
        );
        let sigma = mp.scalar_f32("sigma")?;
        let metric = super::encoded::metric_from_mapped(mp)?;
        let width = mp.scalar_i32("blocked_width")?;
        let block = mp.scalar_i32("blocked_block")?;

        let (cendims, cents) = mp.segment::<f32>("ivf_centroids")?;
        ensure!(
            cendims.len() == 2 && cendims[0] >= 1,
            "ivf_centroids must be [ncells >= 1, d]"
        );
        let (ncells, d) = (cendims[0], cendims[1]);
        ensure!(
            d == codebooks.d(),
            "ivf_centroids dim {d} != codebook dim {}",
            codebooks.d()
        );
        let residual = match mp.scalar_i32("ivf_residual")? {
            0 => false,
            1 => true,
            other => bail!("ivf_residual must be 0 or 1, got {other}"),
        };
        ensure!(
            !residual || metric == Metric::L2,
            "ivf residual snapshots are L2-only; this one is tagged {metric}"
        );

        let (sdims, sizes_seg) = mp.segment::<i32>("ivf_cell_sizes")?;
        ensure!(
            sdims == [ncells].as_slice(),
            "ivf_cell_sizes must be [ncells]"
        );
        let sizes: Vec<i32> = sizes_seg.to_vec();
        let mut total = 0usize;
        for &s in &sizes {
            ensure!(s >= 0, "ivf_cell_sizes holds a negative size {s}");
            total += s as usize;
        }
        ensure!(
            total == n,
            "ivf_cell_sizes sum to {total} but the index holds {n} rows"
        );

        let (gdims, globals_seg) = mp.segment::<i32>("ivf_row_global")?;
        ensure!(
            gdims == [n].as_slice(),
            "ivf_row_global must be [n]"
        );
        let globals: Vec<i32> = globals_seg.to_vec();
        let mut seen = vec![false; n];
        for &g in &globals {
            ensure!(
                g >= 0 && (g as usize) < n,
                "ivf_row_global id {g} out of [0, {n})"
            );
            ensure!(!seen[g as usize], "duplicate global row id {g}");
            seen[g as usize] = true;
        }

        let mut cells = Vec::with_capacity(ncells);
        let mut off = 0usize;
        for (c, &sz) in sizes.iter().enumerate() {
            let sz = sz as usize;
            let ids: Vec<u32> =
                globals[off..off + sz].iter().map(|&g| g as u32).collect();
            ensure!(
                ids.windows(2).all(|w| w[0] < w[1]),
                "cell row ids must be strictly ascending (parity invariant)"
            );
            let cell = if sz == 0 {
                // empty cells write no blocked segment; assembling
                // them owned is O(1)
                EncodedIndex::assemble_shared(
                    codebooks.clone(),
                    lut_ctx.clone(),
                    Codes::zeros(0, k),
                    fast_k as usize,
                    sigma,
                    metric,
                    CowSlice::default(),
                )
            } else {
                let codes = Codes::from_cow(
                    sz,
                    k,
                    CowSlice::Mapped(
                        codes_seg.slice(off * k..(off + sz) * k),
                    ),
                )?;
                let blocked = blocked_from_mapped(
                    mp,
                    &format!("ivf_cell{c:05}."),
                    sz,
                    k,
                    m,
                    width,
                    block,
                )?;
                EncodedIndex::assemble_from_parts(
                    codebooks.clone(),
                    lut_ctx.clone(),
                    codes,
                    blocked,
                    fast_k as usize,
                    sigma,
                    metric,
                    CowSlice::Mapped(labels_seg.slice(off..off + sz)),
                )?
            };
            cells.push(Some(IvfCell {
                index: Arc::new(cell),
                ids: Arc::new(ids),
            }));
            off += sz;
        }
        let centroids = Matrix::from_vec(ncells, d, cents.to_vec());
        Ok(IvfIndex {
            centroids: Arc::new(centroids),
            cells,
            residual,
            n_total: n,
            n_owned: n,
        })
    }
}

/// Whether `pack` carries an IVF coarse partition (vs a flat index).
pub fn is_ivf_pack(pack: &TensorPack) -> bool {
    matches!(
        super::snapshot::SnapshotKind::of_pack(pack),
        super::snapshot::SnapshotKind::Ivf
    )
}

/// A loaded index snapshot: flat or IVF-partitioned.
#[derive(Clone, Debug)]
pub enum AnyIndex {
    /// A plain exhaustive-scan index (pre-IVF snapshots land here).
    Flat(EncodedIndex),
    /// An index carrying a coarse partition.
    Ivf(Box<IvfIndex>),
}

/// Load either snapshot flavor: packs without the `ivf_*` tensors are
/// flat indexes (old snapshots keep loading unchanged); packs with
/// them are validated and cut into cells. Dispatch is the exhaustive
/// [`SnapshotKind`] probe shared with the wire-shard loader, so the
/// two loaders can never disagree about what a snapshot is.
///
/// [`SnapshotKind`]: super::snapshot::SnapshotKind
pub fn load_index(pack: &TensorPack) -> Result<AnyIndex> {
    use super::snapshot::SnapshotKind;
    match SnapshotKind::of_pack(pack) {
        SnapshotKind::Ivf => {
            Ok(AnyIndex::Ivf(Box::new(IvfIndex::from_pack(pack)?)))
        }
        // a wire shard's base tensors are a plain flat index; its
        // placement scalars are ignored on the in-process path
        SnapshotKind::Flat | SnapshotKind::Shard => {
            Ok(AnyIndex::Flat(EncodedIndex::from_pack(pack)?))
        }
    }
}

/// [`load_index`] for a mapped icqfmt2 snapshot: same dispatch, but
/// the loaded index adopts the file's payload segments zero-copy.
pub fn load_index_mapped(mp: &MappedPack) -> Result<AnyIndex> {
    use super::snapshot::SnapshotKind;
    match SnapshotKind::of_mapped(mp) {
        SnapshotKind::Ivf => {
            Ok(AnyIndex::Ivf(Box::new(IvfIndex::from_mapped(mp)?)))
        }
        SnapshotKind::Flat | SnapshotKind::Shard => {
            Ok(AnyIndex::Flat(EncodedIndex::from_mapped(mp)?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;
    use crate::quantizer::icq::{Icq, IcqOpts};
    use crate::quantizer::pq::{Pq, PqOpts};

    fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, j| {
            rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
        })
    }

    fn icq_index(n: usize, d: usize, seed: u64) -> (EncodedIndex, Matrix) {
        let x = hetero(n, d, seed);
        let icq = Icq::train(
            &x,
            IcqOpts {
                k: 4,
                m: 16,
                fast_k: 1,
                kmeans_iters: 5,
                prior_steps: 60,
                seed,
            },
        );
        let labels = (0..n).map(|i| i as i32).collect();
        (EncodedIndex::build_icq(&icq, &x, labels), x)
    }

    #[test]
    fn partition_covers_every_row_exactly_once() {
        let (idx, x) = icq_index(130, 12, 1);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 7, iters: 8, seed: 0 },
        )
        .unwrap();
        assert_eq!(ivf.n_total(), 130);
        assert_eq!(ivf.len(), 130);
        let mut seen = vec![false; 130];
        for c in 0..ivf.ncells() {
            let cell = ivf.cell(c).unwrap();
            assert_eq!(cell.index.len(), cell.ids.len());
            assert!(cell.ids.windows(2).all(|w| w[0] < w[1]));
            for (li, &g) in cell.ids.iter().enumerate() {
                assert!(!seen[g as usize]);
                seen[g as usize] = true;
                // codes gathered, not re-encoded
                for kk in 0..idx.k() {
                    assert_eq!(
                        cell.index.codes().get(li, kk),
                        idx.codes().get(g as usize, kk)
                    );
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn full_probe_matches_flat_search() {
        let (idx, x) = icq_index(150, 12, 2);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 6, iters: 8, seed: 0 },
        )
        .unwrap();
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
        let mut crude = Vec::new();
        for qi in 0..8 {
            let q = x.row(qi * 17 % 150);
            let flat = search_icq::search_scanfirst_query_qlut(
                &idx, q, opts, &ops, &mut crude,
            );
            let got = ivf.search(q, ivf.ncells(), opts, &ops);
            assert_eq!(got, flat, "query {qi}");
        }
    }

    #[test]
    fn duplicate_points_leave_empty_cells_and_search_survives() {
        // 2 distinct points, 6 requested cells: at most 2 cells can be
        // non-empty (ties assign to the lowest-index centroid), so the
        // probe path must skip empties without dropping hits.
        let n = 40;
        let x = Matrix::from_fn(n, 4, |i, j| {
            if i % 2 == 0 {
                j as f32
            } else {
                10.0 + j as f32
            }
        });
        let pq = Pq::train(&x, PqOpts { k: 2, m: 4, iters: 4, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; n]);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 6, iters: 6, seed: 0 },
        )
        .unwrap();
        let empty = (0..ivf.ncells())
            .filter(|&c| ivf.cell(c).unwrap().index.is_empty())
            .count();
        assert!(empty >= 4, "expected >= 4 empty cells, got {empty}");
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 50, margin_scale: 1.0 };
        let mut crude = Vec::new();
        let flat = search_icq::search_scanfirst_query_qlut(
            &idx,
            x.row(0),
            opts,
            &ops,
            &mut crude,
        );
        let got = ivf.search(x.row(0), ivf.ncells(), opts, &ops);
        assert_eq!(got, flat);
        assert_eq!(got.len(), n.min(50));
    }

    #[test]
    fn split_cells_deals_every_owned_cell_once() {
        let (idx, x) = icq_index(120, 12, 3);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 5, iters: 6, seed: 0 },
        )
        .unwrap();
        let shards = ivf.split_cells(3).unwrap();
        assert_eq!(shards.len(), 3);
        let mut owned = vec![0usize; ivf.ncells()];
        let mut rows = 0;
        for s in &shards {
            assert_eq!(s.ncells(), ivf.ncells());
            assert_eq!(s.n_total(), ivf.n_total());
            rows += s.len();
            for c in 0..s.ncells() {
                if s.cell(c).is_some() {
                    owned[c] += 1;
                }
            }
        }
        assert_eq!(rows, ivf.len());
        assert!(owned.iter().all(|&o| o == 1));
    }

    #[test]
    fn pack_roundtrip_preserves_search_bitwise() {
        let (idx, x) = icq_index(100, 12, 4);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 5, iters: 6, seed: 0 },
        )
        .unwrap();
        let pack = ivf.to_pack();
        let back = IvfIndex::from_pack(&pack).unwrap();
        assert_eq!(back.ncells(), ivf.ncells());
        assert!(!back.residual());
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
        for qi in 0..5 {
            let q = x.row(qi * 13);
            for nprobe in [1, 2, ivf.ncells()] {
                assert_eq!(
                    back.search(q, nprobe, opts, &ops),
                    ivf.search(q, nprobe, opts, &ops)
                );
            }
        }
        // flat packs (no ivf tensors) still load as flat
        match load_index(&idx.to_pack()).unwrap() {
            AnyIndex::Flat(f) => assert_eq!(f.len(), idx.len()),
            AnyIndex::Ivf(_) => panic!("flat pack loaded as IVF"),
        }
        match load_index(&pack).unwrap() {
            AnyIndex::Ivf(i) => assert_eq!(i.n_total(), 100),
            AnyIndex::Flat(_) => panic!("ivf pack loaded as flat"),
        }
    }

    #[test]
    fn mapped_roundtrip_preserves_search_bitwise() {
        let (idx, x) = icq_index(130, 12, 7);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 5, iters: 6, seed: 0 },
        )
        .unwrap();
        let bytes =
            crate::data::mapped::write_mapped(&ivf.to_mapped_tensors());
        let mp = MappedPack::from_bytes(&bytes).unwrap();
        let back = IvfIndex::from_mapped(&mp).unwrap();
        assert_eq!(back.ncells(), ivf.ncells());
        assert_eq!(back.n_total(), ivf.n_total());
        assert!(!back.residual());
        for c in 0..ivf.ncells() {
            let (a, b) = (ivf.cell(c).unwrap(), back.cell(c).unwrap());
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.index.codes(), b.index.codes());
            assert_eq!(a.index.labels, b.index.labels);
            if !a.index.is_empty() {
                // the payload is adopted from the file, not copied
                assert!(b.index.labels.is_mapped());
                assert!(b.index.blocked().is_mapped());
            }
        }
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
        for qi in 0..5 {
            let q = x.row(qi * 13);
            for nprobe in [1, 2, ivf.ncells()] {
                assert_eq!(
                    back.search(q, nprobe, opts, &ops),
                    ivf.search(q, nprobe, opts, &ops)
                );
            }
        }
        // the mapped dispatcher agrees with the pack dispatcher
        match load_index_mapped(&mp).unwrap() {
            AnyIndex::Ivf(i) => assert_eq!(i.n_total(), 130),
            AnyIndex::Flat(_) => panic!("ivf snapshot opened as flat"),
        }
        let fb = crate::data::mapped::write_mapped(&idx.to_mapped_tensors());
        match load_index_mapped(&MappedPack::from_bytes(&fb).unwrap()).unwrap()
        {
            AnyIndex::Flat(f) => assert_eq!(f.len(), idx.len()),
            AnyIndex::Ivf(_) => panic!("flat snapshot opened as IVF"),
        }
    }

    #[test]
    fn from_mapped_rejects_corrupt_partitions() {
        fn reopen(pack: &TensorPack) -> Result<IvfIndex> {
            let bytes = crate::data::mapped::write_mapped(pack);
            IvfIndex::from_mapped(&MappedPack::from_bytes(&bytes)?)
        }
        let (idx, x) = icq_index(60, 12, 8);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 4, iters: 6, seed: 0 },
        )
        .unwrap();
        let good = ivf.to_mapped_tensors();
        assert!(reopen(&good).is_ok());

        // future version
        let mut bad = good.clone();
        bad.insert_i32("ivf_version", vec![1], vec![99]);
        assert!(reopen(&bad).is_err());

        // sizes that do not sum to n
        let mut bad = good.clone();
        let mut wrong = good.i32("ivf_cell_sizes").unwrap().1.to_vec();
        wrong[0] += 1;
        bad.insert_i32("ivf_cell_sizes", vec![wrong.len()], wrong);
        assert!(reopen(&bad).is_err());

        // duplicate global id
        let mut bad = good.clone();
        let mut globals = good.i32("ivf_row_global").unwrap().1.to_vec();
        globals[1] = globals[0];
        bad.insert_i32("ivf_row_global", vec![globals.len()], globals);
        assert!(reopen(&bad).is_err());

        // a non-empty cell's blocked transpose segment missing
        let mut bad = good.clone();
        let name = bad
            .tensors
            .keys()
            .find(|t| t.starts_with("ivf_cell") && t.contains("blocked"))
            .expect("partition has a non-empty cell")
            .clone();
        bad.tensors.remove(&name);
        assert!(reopen(&bad).is_err());
    }

    #[test]
    fn from_pack_rejects_corrupt_partitions() {
        let (idx, x) = icq_index(60, 12, 5);
        let ivf = IvfIndex::partition(
            &idx,
            &x,
            IvfBuildOpts { ncells: 4, iters: 6, seed: 0 },
        )
        .unwrap();
        let good = ivf.to_pack();
        assert!(IvfIndex::from_pack(&good).is_ok());

        // future version
        let mut bad = good.clone();
        bad.insert_i32("ivf_version", vec![1], vec![99]);
        assert!(IvfIndex::from_pack(&bad).is_err());

        // sizes that do not sum to n
        let mut bad = good.clone();
        let sizes = good.i32("ivf_cell_sizes").unwrap().1.to_vec();
        let mut wrong = sizes.clone();
        wrong[0] += 1;
        bad.insert_i32("ivf_cell_sizes", vec![wrong.len()], wrong);
        assert!(IvfIndex::from_pack(&bad).is_err());

        // duplicate global id
        let mut bad = good.clone();
        let mut globals = good.i32("ivf_row_global").unwrap().1.to_vec();
        globals[1] = globals[0];
        bad.insert_i32("ivf_row_global", vec![globals.len()], globals);
        assert!(IvfIndex::from_pack(&bad).is_err());

        // out-of-range global id
        let mut bad = good.clone();
        let mut globals = good.i32("ivf_row_global").unwrap().1.to_vec();
        globals[0] = 60;
        bad.insert_i32("ivf_row_global", vec![globals.len()], globals);
        assert!(IvfIndex::from_pack(&bad).is_err());
    }

    #[test]
    fn residual_mode_searches_and_roundtrips() {
        let n = 160;
        let x = hetero(n, 12, 6);
        let icq = Icq::train(
            &x,
            IcqOpts {
                k: 4,
                m: 16,
                fast_k: 1,
                kmeans_iters: 5,
                prior_steps: 60,
                seed: 6,
            },
        );
        let labels: Vec<i32> = (0..n).map(|i| i as i32).collect();
        let ivf = IvfIndex::build_residual(
            &icq,
            &x,
            &labels,
            icq.fast_k,
            icq.sigma,
            IvfBuildOpts { ncells: 6, iters: 8, seed: 0 },
        )
        .unwrap();
        assert!(ivf.residual());
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
        let hits = ivf.search(x.row(3), ivf.ncells(), opts, &ops);
        assert_eq!(hits.len(), 10);
        assert!(hits
            .windows(2)
            .all(|w| (w[0].dist, w[0].id) <= (w[1].dist, w[1].id)));
        assert!(hits.iter().all(|h| (h.id as usize) < n));
        // snapshot roundtrip is bitwise for residual mode too
        let back = IvfIndex::from_pack(&ivf.to_pack()).unwrap();
        assert!(back.residual());
        assert_eq!(
            back.search(x.row(3), 3, opts, &ops),
            ivf.search(x.row(3), 3, opts, &ops)
        );
    }
}
