//! Conventional ADC search (eq. 1) — the baseline scan every prior VQ
//! method uses: per candidate, sum K LUT entries and offer to the top-k
//! heap. Exactly K table-adds per candidate, which the counters record.
//!
//! The dense distance pass sweeps the index's [`BlockedCodes`] (book-major
//! blocks; see [`super::blocked`]): per block, each LUT row is loaded once
//! and added across B contiguous codes. Accumulation order per vector is
//! books-ascending, so results are bitwise identical to the row-major
//! reference scan kept in [`search_with_lut_rowmajor`] (the parity oracle
//! the kernels bench and property tests compare against).

use crate::core::parallel::par_map_indexed;

use super::blocked::{BlockedCodes, BlockedStore, CodeUnit};
use super::encoded::EncodedIndex;
use super::lut::Lut;
use super::opcount::OpCounter;
use crate::core::{Hit, Matrix, TopK};

/// ADC k-NN for one query (pre-embedded, same space as the index).
/// Metric-aware: similarity indexes sweep the same blocked kernels
/// over `<q, c>` LUT entries into a keep-largest top-k — a full K-term
/// sum is the exact quantized score for every metric, so no bound
/// logic is needed here (this is the parity oracle the two-step paths
/// are checked against).
pub fn search(
    index: &EncodedIndex,
    q: &[f32],
    k: usize,
    ops: &OpCounter,
) -> Vec<Hit> {
    let lut =
        Lut::build_metric(index.lut_ctx(), index.codebooks(), q, index.metric);
    // compact-support LUT build: m * sum|support_k| MACs (see index/lut.rs)
    ops.add_flops(index.lut_ctx().build_macs() as u64);
    search_with_lut(index, &lut, k, ops)
}

/// Blockwise full-ADC sweep into a top-k heap (books `[0, K)`).
/// Dispatches on the stored code width once; the block loop below is
/// monomorphized per width.
fn scan_blocked(index: &EncodedIndex, lut: &Lut, top: &mut TopK) {
    let kb = index.k();
    match index.blocked() {
        BlockedStore::U8(b) => scan_blocked_width(b, lut, kb, top),
        BlockedStore::U16(b) => scan_blocked_width(b, lut, kb, top),
    }
}

fn scan_blocked_width<C: CodeUnit>(
    blocked: &BlockedCodes<C>,
    lut: &Lut,
    kb: usize,
    top: &mut TopK,
) {
    let bs = blocked.block_size();
    let mut acc = vec![0.0f32; bs];
    for b in 0..blocked.num_blocks() {
        blocked.block_partial_sums(lut, 0, kb, b, &mut acc);
        let base = b * bs;
        for (j, &d) in acc[..blocked.block_len(b)].iter().enumerate() {
            top.push((base + j) as u32, d);
        }
    }
}

/// ADC scan given a prebuilt LUT (the PJRT runtime path feeds LUTs
/// computed by the AOT graph).
pub fn search_with_lut(
    index: &EncodedIndex,
    lut: &Lut,
    k: usize,
    ops: &OpCounter,
) -> Vec<Hit> {
    let mut top = TopK::new_metric(k, index.metric);
    scan_blocked(index, lut, &mut top);
    ops.add_queries(1);
    ops.add_candidates(index.len() as u64);
    ops.add_table_adds((index.len() * index.k()) as u64);
    top.into_sorted()
}

/// Row-major reference scan — the parity oracle for the blocked sweep.
/// Same op accounting as [`search_with_lut`].
pub fn search_with_lut_rowmajor(
    index: &EncodedIndex,
    lut: &Lut,
    k: usize,
    ops: &OpCounter,
) -> Vec<Hit> {
    let kb = index.k();
    let codes = index.codes();
    let mut top = TopK::new_metric(k, index.metric);
    for i in 0..index.len() {
        let d = lut.partial_sum(codes.row(i), 0, kb);
        top.push(i as u32, d);
    }
    ops.add_queries(1);
    ops.add_candidates(index.len() as u64);
    ops.add_table_adds((index.len() * kb) as u64);
    top.into_sorted()
}

/// Batch ADC (parallel over queries, blocked sweep each).
pub fn search_batch(
    index: &EncodedIndex,
    queries: &Matrix,
    k: usize,
    ops: &OpCounter,
) -> Vec<Vec<Hit>> {
    let res: Vec<Vec<Hit>> = par_map_indexed(queries.rows(), |qi| {
        let lut = Lut::build_metric(
            index.lut_ctx(),
            index.codebooks(),
            queries.row(qi),
            index.metric,
        );
        let mut top = TopK::new_metric(k, index.metric);
        scan_blocked(index, &lut, &mut top);
        top.into_sorted()
    });
    ops.add_queries(queries.rows() as u64);
    ops.add_candidates((queries.rows() * index.len()) as u64);
    ops.add_table_adds((queries.rows() * index.len() * index.k()) as u64);
    ops.add_flops((queries.rows() * index.lut_ctx().build_macs()) as u64);
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Rng;
    use crate::index::search_exact;
    use crate::quantizer::pq::{Pq, PqOpts};

    fn setup() -> (Matrix, EncodedIndex) {
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(300, 8, |_, _| rng.normal_f32());
        let pq = Pq::train(&x, PqOpts { k: 4, m: 32, iters: 15, seed: 0 });
        let idx = EncodedIndex::build(&pq, &x, vec![0; 300]);
        (x, idx)
    }

    #[test]
    fn counts_k_adds_per_candidate() {
        let (_, idx) = setup();
        let ops = OpCounter::new();
        let q = vec![0.0f32; 8];
        search(&idx, &q, 5, &ops);
        assert_eq!(ops.snapshot().candidates, 300);
        assert_eq!(ops.snapshot().table_adds, 300 * 4);
        assert_eq!(ops.avg_ops_per_candidate(), 4.0);
    }

    #[test]
    fn lut_build_charges_compact_support_flops() {
        let (_, idx) = setup();
        let ops = OpCounter::new();
        let q = vec![0.0f32; 8];
        search(&idx, &q, 5, &ops);
        // PQ supports partition the dims, so the compact build is
        // m * d MACs total, NOT K * m * d
        assert_eq!(ops.snapshot().flops, 32 * 8);
        assert_eq!(idx.lut_ctx().build_macs(), 32 * 8);
    }

    #[test]
    fn blocked_scan_matches_rowmajor_oracle() {
        let (_, idx) = setup();
        let mut rng = Rng::new(31);
        for _ in 0..8 {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let lut = Lut::build(idx.lut_ctx(), idx.codebooks(), &q);
            let ops = OpCounter::new();
            let blocked = search_with_lut(&idx, &lut, 10, &ops);
            let rowmajor = search_with_lut_rowmajor(&idx, &lut, 10, &ops);
            assert_eq!(blocked, rowmajor);
        }
    }

    #[test]
    fn adc_recall_reasonable_vs_exact() {
        let (x, idx) = setup();
        let ops = OpCounter::new();
        let mut rng = Rng::new(77);
        let mut overlap = 0usize;
        let trials = 20;
        let r = 10;
        for _ in 0..trials {
            let q: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let exact = search_exact::search(&x, &q, r, &ops);
            let adc = search(&idx, &q, r, &ops);
            let exact_ids: std::collections::HashSet<u32> =
                exact.iter().map(|h| h.id).collect();
            overlap += adc.iter().filter(|h| exact_ids.contains(&h.id)).count();
        }
        let recall = overlap as f64 / (trials * r) as f64;
        assert!(recall > 0.4, "ADC recall@10 unreasonably low: {recall}");
    }

    #[test]
    fn batch_matches_single() {
        let (_, idx) = setup();
        let mut rng = Rng::new(9);
        let q = Matrix::from_fn(4, 8, |_, _| rng.normal_f32());
        let ops = OpCounter::new();
        let batch = search_batch(&idx, &q, 5, &ops);
        for i in 0..4 {
            let single = search(&idx, q.row(i), 5, &ops);
            assert_eq!(batch[i], single);
        }
    }
}
