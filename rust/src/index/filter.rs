//! Per-vector metadata predicates for filtered search.
//!
//! A [`RowFilter`] is a bitmap over database rows — bit `i` set means
//! row `i` may be returned. Filters are evaluated between the blocked
//! crude sweep and the refine: every disallowed row's crude entry is
//! masked to the metric's worst value ([`RowFilter::mask_crude`]), so
//! masked rows never seed the pruning radius, never survive the dense
//! cut, and never enter a [`crate::core::TopK`] — the filtered top-k is
//! exactly the unfiltered ranking restricted to allowed rows.
//!
//! The word layout is deliberately block-aligned: one `u64` word covers
//! one default-sized code block (`blocked::DEFAULT_BLOCK` = 64 lanes),
//! so the mask loop can skip fully-allowed words with a single compare
//! and the sharded path can cut filters at block boundaries without
//! bit-shifting ([`RowFilter::slice`] keeps a shift-free fast path for
//! word-aligned cuts).

/// An allow-list bitmap over `n` database rows (bit set = allowed).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowFilter {
    n: usize,
    /// `ceil(n / 64)` little-endian words; bit `i % 64` of word
    /// `i / 64` is row `i`. Bits at positions `>= n` are always zero.
    words: Vec<u64>,
}

impl RowFilter {
    /// Number of words covering `n` rows.
    #[inline]
    pub fn words_for(n: usize) -> usize {
        n.div_ceil(64)
    }

    /// Build from raw words. Fails (returns `None`) when the word count
    /// is wrong or a bit past `n` is set — the strictness matters
    /// because filters cross the wire, where a sloppy tail bit would
    /// make two honest ends disagree on [`Self::count`].
    pub fn from_words(n: usize, words: Vec<u64>) -> Option<RowFilter> {
        if words.len() != Self::words_for(n) {
            return None;
        }
        if n % 64 != 0 {
            if let Some(&last) = words.last() {
                if last >> (n % 64) != 0 {
                    return None;
                }
            }
        }
        Some(RowFilter { n, words })
    }

    /// An all-zero (nothing allowed) filter over `n` rows.
    pub fn none(n: usize) -> RowFilter {
        RowFilter { n, words: vec![0; Self::words_for(n)] }
    }

    /// An all-ones (everything allowed) filter over `n` rows.
    pub fn all(n: usize) -> RowFilter {
        let mut f = RowFilter { n, words: vec![u64::MAX; Self::words_for(n)] };
        f.clear_tail();
        f
    }

    /// Build from an explicit id list; ids `>= n` are ignored.
    pub fn from_indices(n: usize, ids: &[u32]) -> RowFilter {
        let mut f = RowFilter::none(n);
        for &id in ids {
            let i = id as usize;
            if i < n {
                f.words[i / 64] |= 1u64 << (i % 64);
            }
        }
        f
    }

    fn clear_tail(&mut self) {
        if self.n % 64 != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << (self.n % 64)) - 1;
            }
        }
    }

    /// Rows covered.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the filter covers zero rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Whether row `i` may be returned.
    #[inline]
    pub fn allows(&self, i: usize) -> bool {
        debug_assert!(i < self.n);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    /// Number of allowed rows.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// The raw words (for wire serialization).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// The filter restricted to global rows `[start, end)`, re-indexed
    /// from zero — how the gather hands each shard its slice of a
    /// global filter. Word-aligned starts (every block-aligned shard
    /// cut) copy words; others shift.
    pub fn slice(&self, start: usize, end: usize) -> RowFilter {
        assert!(start <= end && end <= self.n, "bad filter slice");
        let n = end - start;
        let out_words = Self::words_for(n);
        let mut words = Vec::with_capacity(out_words);
        if start % 64 == 0 {
            let w0 = start / 64;
            words.extend_from_slice(&self.words[w0..w0 + out_words]);
        } else {
            let (w0, sh) = (start / 64, start % 64);
            for wi in 0..out_words {
                let lo = self.words[w0 + wi] >> sh;
                let hi = match self.words.get(w0 + wi + 1) {
                    Some(&w) => w << (64 - sh),
                    None => 0,
                };
                words.push(lo | hi);
            }
        }
        let mut f = RowFilter { n, words };
        f.clear_tail();
        f
    }

    /// Overwrite `crude[i]` with `worst` for every disallowed row
    /// `row0 + i` — the masking step between the crude sweep and the
    /// refine. `worst` is the metric's sentinel
    /// ([`crate::core::Metric::worst`]): `+inf` for L2, `-inf` for
    /// similarities. Fully-allowed words are skipped with one compare.
    pub fn mask_crude(&self, crude: &mut [f32], row0: usize, worst: f32) {
        debug_assert!(row0 + crude.len() <= self.n);
        let mut i = 0usize;
        while i < crude.len() {
            let row = row0 + i;
            let w = self.words[row / 64];
            let bit = row % 64;
            // word-aligned whole-word fast paths: all-allowed words are
            // skipped, all-denied words fill in one memset
            if bit == 0 && crude.len() - i >= 64 {
                if w == u64::MAX {
                    i += 64;
                    continue;
                }
                if w == 0 {
                    crude[i..i + 64].fill(worst);
                    i += 64;
                    continue;
                }
            }
            if w & (1u64 << bit) == 0 {
                crude[i] = worst;
            }
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_words_validates_shape_and_tail() {
        assert!(RowFilter::from_words(100, vec![0; 2]).is_some());
        assert!(RowFilter::from_words(100, vec![0; 1]).is_none());
        assert!(RowFilter::from_words(100, vec![0; 3]).is_none());
        // bit 100 set in a 100-row filter: rejected
        let mut w = vec![0u64; 2];
        w[1] = 1u64 << 36;
        assert!(RowFilter::from_words(100, w).is_none());
        // bit 99: fine
        let mut w = vec![0u64; 2];
        w[1] = 1u64 << 35;
        assert!(RowFilter::from_words(100, w).is_some());
        assert!(RowFilter::from_words(0, vec![]).is_some());
    }

    #[test]
    fn indices_round_trip_through_allows_and_count() {
        let ids = [0u32, 3, 63, 64, 99];
        let f = RowFilter::from_indices(100, &ids);
        assert_eq!(f.count(), ids.len());
        for i in 0..100 {
            assert_eq!(f.allows(i), ids.contains(&(i as u32)));
        }
        // out-of-range ids are dropped
        let g = RowFilter::from_indices(10, &[5, 10, 200]);
        assert_eq!(g.count(), 1);
        assert_eq!(RowFilter::all(70).count(), 70);
        assert_eq!(RowFilter::none(70).count(), 0);
    }

    #[test]
    fn slices_match_bitwise_reference() {
        let ids: Vec<u32> = (0..300).filter(|i| i % 7 == 0).collect();
        let f = RowFilter::from_indices(300, &ids);
        for (start, end) in
            [(0usize, 300usize), (64, 192), (3, 300), (65, 131), (100, 100)]
        {
            let s = f.slice(start, end);
            assert_eq!(s.len(), end - start);
            for i in 0..s.len() {
                assert_eq!(
                    s.allows(i),
                    f.allows(start + i),
                    "slice [{start},{end}) bit {i}"
                );
            }
        }
    }

    #[test]
    fn mask_crude_replaces_disallowed_entries_only() {
        let f = RowFilter::from_indices(130, &[0, 1, 64, 129]);
        let mut crude: Vec<f32> = (0..130).map(|i| i as f32).collect();
        f.mask_crude(&mut crude, 0, f32::INFINITY);
        for i in 0..130 {
            if f.allows(i) {
                assert_eq!(crude[i], i as f32);
            } else {
                assert_eq!(crude[i], f32::INFINITY);
            }
        }
        // range variant with offset and the all-ones fast path
        let all = RowFilter::all(130);
        let mut c2: Vec<f32> = (0..64).map(|i| i as f32).collect();
        all.mask_crude(&mut c2, 64, f32::NEG_INFINITY);
        assert!(c2.iter().enumerate().all(|(i, &v)| v == i as f32));
        let mut c3: Vec<f32> = (0..66).map(|i| i as f32).collect();
        f.mask_crude(&mut c3, 64, f32::NEG_INFINITY);
        assert_eq!(c3[0], 0.0); // row 64 allowed
        assert_eq!(c3[1], f32::NEG_INFINITY); // row 65 disallowed
    }
}
