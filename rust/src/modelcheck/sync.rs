//! Model-aware drop-in replacements for `std::sync::Mutex` and
//! `std::sync::Condvar`.
//!
//! A `Mutex`/`Condvar` created **inside** a running [`super::model`]
//! registers with that schedule's scheduler: every lock, unlock, wait,
//! and notify becomes a schedule point the checker explores. Created
//! anywhere else (production, ordinary tests), the types delegate
//! straight to their `std` counterparts — the only overhead is one
//! `Option` check per operation, and the API mirrors `std` so
//! `coordinator::sync` can re-export them as the coordinator's only
//! sync primitives.
//!
//! Poisoning: the model path never poisons (a participant panic aborts
//! the schedule through the scheduler instead); the delegating path
//! forwards `std`'s poison semantics untouched.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, LockResult, PoisonError, TryLockError, TryLockResult};
use std::sync::{Condvar as OsCondvar, Mutex as OsMutex, MutexGuard as OsMutexGuard};

use super::{
    acquire_mutex, current, cv_notify, cv_wait, register_condvar, register_mutex,
    release_mutex, try_acquire_mutex, Participant, Shared,
};

/// Scheduler registration of a primitive created inside a model.
struct ModelRef {
    shared: Arc<Shared>,
    id: usize,
}

impl ModelRef {
    /// The calling thread's participant handle, if it belongs to the
    /// same schedule this primitive registered with.
    fn participant(&self) -> Option<Participant> {
        let p = current()?;
        if Arc::ptr_eq(&self.shared, &p.shared) {
            Some(p)
        } else {
            None
        }
    }
}

fn register() -> Option<ModelRef> {
    current().map(|p| ModelRef {
        id: register_mutex(&p),
        shared: p.shared,
    })
}

fn register_cv() -> Option<ModelRef> {
    current().map(|p| ModelRef {
        id: register_condvar(&p),
        shared: p.shared,
    })
}

/// A mutual-exclusion lock with the `std::sync::Mutex` API; modeled as
/// a schedule point when created inside [`super::model`].
pub struct Mutex<T> {
    model: Option<ModelRef>,
    inner: OsMutex<T>,
}

impl<T> Mutex<T> {
    /// Create a new mutex holding `value`.
    pub fn new(value: T) -> Self {
        Mutex {
            model: register(),
            inner: OsMutex::new(value),
        }
    }

    /// Consume the mutex, returning the protected value.
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    /// Acquire the lock, blocking until it is available.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some(p) = self.model.as_ref().and_then(ModelRef::participant) {
            let slot = self.model.as_ref().map(|m| m.id).unwrap_or(0);
            acquire_mutex(&p, slot);
            let guard = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard { lock: self, inner: Some(guard) });
        }
        match self.inner.lock() {
            Ok(guard) => Ok(MutexGuard { lock: self, inner: Some(guard) }),
            Err(poisoned) => Err(PoisonError::new(MutexGuard {
                lock: self,
                inner: Some(poisoned.into_inner()),
            })),
        }
    }

    /// Attempt to acquire the lock without blocking.
    pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
        if let Some(p) = self.model.as_ref().and_then(ModelRef::participant) {
            let slot = self.model.as_ref().map(|m| m.id).unwrap_or(0);
            if !try_acquire_mutex(&p, slot) {
                return Err(TryLockError::WouldBlock);
            }
            let guard = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard { lock: self, inner: Some(guard) });
        }
        match self.inner.try_lock() {
            Ok(guard) => Ok(MutexGuard { lock: self, inner: Some(guard) }),
            Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
            Err(TryLockError::Poisoned(poisoned)) => {
                Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(poisoned.into_inner()),
                })))
            }
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // debug-format through the OS mutex without a schedule point
        match self.inner.try_lock() {
            Ok(guard) => f.debug_struct("Mutex").field("data", &&*guard).finish(),
            Err(_) => f.debug_struct("Mutex").field("data", &"<locked>").finish(),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

/// RAII guard returned by [`Mutex::lock`]; releases on drop (a schedule
/// point inside a model).
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<OsMutexGuard<'a, T>>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match self.inner.as_ref() {
            Some(guard) => guard,
            None => unreachable!("guard accessed after release"),
        }
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match self.inner.as_mut() {
            Some(guard) => guard,
            None => unreachable!("guard accessed after release"),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&**self, f)
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // free the OS lock first, then the scheduler's ledger slot —
        // the next participant granted the ledger must find it free
        drop(self.inner.take());
        if let Some(model) = self.lock.model.as_ref() {
            if let Some(p) = model.participant() {
                release_mutex(&p, model.id);
            }
        }
    }
}

/// A condition variable with the `std::sync::Condvar` API; waiter
/// selection under `notify_one` is itself an explored schedule choice
/// inside a model.
pub struct Condvar {
    model: Option<ModelRef>,
    inner: OsCondvar,
}

impl Condvar {
    /// Create a new condition variable.
    pub fn new() -> Self {
        Condvar {
            model: register_cv(),
            inner: OsCondvar::new(),
        }
    }

    /// Release `guard`'s mutex and park until notified; the mutex is
    /// re-acquired before returning.
    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        if let Some(p) = self.model.as_ref().and_then(ModelRef::participant) {
            let cvid = self.model.as_ref().map(|m| m.id).unwrap_or(0);
            let mid = match lock.model.as_ref() {
                Some(m) => m.id,
                None => panic!("modeled Condvar waiting on an unmodeled Mutex"),
            };
            // release the OS lock by hand and skip the guard's Drop:
            // cv_wait owns the ledger hand-off for this wait
            drop(guard.inner.take());
            std::mem::forget(guard);
            cv_wait(&p, cvid, mid);
            let re = lock.inner.lock().unwrap_or_else(PoisonError::into_inner);
            return Ok(MutexGuard { lock, inner: Some(re) });
        }
        let os = match guard.inner.take() {
            Some(g) => g,
            None => unreachable!("guard accessed after release"),
        };
        std::mem::forget(guard);
        match self.inner.wait(os) {
            Ok(re) => Ok(MutexGuard { lock, inner: Some(re) }),
            Err(poisoned) => Err(PoisonError::new(MutexGuard {
                lock,
                inner: Some(poisoned.into_inner()),
            })),
        }
    }

    /// Wake one waiter (scheduler-chosen inside a model).
    pub fn notify_one(&self) {
        if let Some(p) = self.model.as_ref().and_then(ModelRef::participant) {
            let cvid = self.model.as_ref().map(|m| m.id).unwrap_or(0);
            cv_notify(&p, cvid, false);
            return;
        }
        self.inner.notify_one();
    }

    /// Wake every waiter.
    pub fn notify_all(&self) {
        if let Some(p) = self.model.as_ref().and_then(ModelRef::participant) {
            let cvid = self.model.as_ref().map(|m| m.id).unwrap_or(0);
            cv_notify(&p, cvid, true);
            return;
        }
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad("Condvar")
    }
}
