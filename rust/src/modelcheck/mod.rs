//! In-tree exhaustive interleaving model checker (a minimal loom).
//!
//! The vendored registry carries no `loom`, so this module implements
//! the same idea from scratch: run a small concurrent *model* — a
//! closure that spawns a few threads and exercises [`sync::Mutex`] /
//! [`sync::Condvar`] — under **every** schedule the primitives allow,
//! and fail on the first interleaving that panics, asserts, or
//! deadlocks. The coordinator's concurrency hot spots route their lock
//! traffic through `coordinator::sync`, whose `Mutex`/`Condvar` are the
//! model-aware types defined here, so the exact production types are
//! what the models in `tests/loom_models.rs` explore.
//!
//! # How it works
//!
//! Each schedule runs the model closure on real OS threads, but only
//! one thread is ever *runnable*: a token-passing scheduler blocks
//! every participant except the current one, and every sync operation
//! (lock, unlock, condvar wait/notify, spawn, join) is a *schedule
//! point* where the scheduler picks which participant runs next. The
//! sequence of picks is recorded as a trace of `(choice, n_options)`
//! pairs; after a schedule completes, the next schedule replays the
//! longest prefix with the last branchable choice advanced —
//! depth-first search over the full schedule tree. Exploration is
//! exhaustive up to the documented modeling limits, and terminates
//! because every model runs a finite number of schedule points.
//!
//! A deadlock (no participant runnable, not all done) is detected and
//! reported with the failing schedule; so is the first panic raised by
//! any participant (assertion failures inside models are how invariant
//! violations surface).
//!
//! # Modeling limits
//!
//! * Only `sync::Mutex` and `sync::Condvar` create schedule points.
//!   Atomics and `mpsc` channels are deliberately *not* modeled: the
//!   coordinator uses atomics for monotone metrics counters and load
//!   gauges, and `mpsc` for queue plumbing whose blocking behavior the
//!   chaos suite exercises end to end. Models that need a channel build
//!   one from the modeled mutex + condvar (see `tests/loom_models.rs`).
//! * Condvar waits have no spurious wakeups; `notify_one`'s choice of
//!   waiter *is* explored as a schedule choice.
//! * Models must be deterministic: no wall-clock branching, no OS
//!   randomness. Capture `Instant::now()` once per schedule and pass it
//!   around if time values are needed.
//! * Mutexes and condvars must be **created inside** the model closure
//!   (they register with the running schedule); keep models small —
//!   two or three threads and a handful of lock sessions each. The
//!   schedule count is the number of interleavings of the schedule
//!   points, which grows combinatorially.
//!
//! Under `RUSTFLAGS="--cfg loom"` the schedule budget is raised (see
//! [`ModelOpts`]); the exploration itself is identical, so the models
//! in `tests/loom_models.rs` run on plain `cargo test` too.

pub mod sync;

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex, MutexGuard as OsMutexGuard};

/// Panic payload used to unwind participants of an already-failed
/// schedule; never reported as the failure itself.
const ABORT_MSG: &str = "__modelcheck_schedule_aborted__";

/// What a participant thread is doing, from the scheduler's view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TState {
    /// Can run user code when given the token.
    Runnable,
    /// Blocked acquiring mutex `.0`; runnable once it is free.
    WantsLock(usize),
    /// Parked on condvar `cv`, will re-acquire `mutex` when notified.
    WaitingCv { cv: usize, mutex: usize },
    /// Blocked joining participant `.0`; runnable once it is done.
    Joining(usize),
    /// Finished (returned or unwound).
    Done,
}

/// Scheduler state for one schedule of one model.
struct Inner {
    threads: Vec<TState>,
    /// The participant holding the run token.
    cur: usize,
    /// Ledger of mutex ownership (index = registration order).
    mutex_owner: Vec<Option<usize>>,
    /// Condvars registered so far (waiters live in `threads`).
    n_condvars: usize,
    /// Forced choices replayed from the previous schedule.
    prefix: Vec<usize>,
    /// Choices taken so far this schedule.
    depth: usize,
    /// `(choice, n_options)` per schedule point, for DFS backtracking.
    trace: Vec<(u32, u32)>,
    /// First failure (panic message or deadlock report), if any.
    failure: Option<String>,
    /// OS handles of spawned participants, joined by the driver.
    handles: Vec<std::thread::JoinHandle<()>>,
    max_threads: usize,
}

/// One schedule's shared scheduler: every sync operation funnels here.
pub(crate) struct Shared {
    inner: OsMutex<Inner>,
    cv: OsCondvar,
}

impl Shared {
    fn new(prefix: Vec<usize>, max_threads: usize) -> Self {
        Shared {
            inner: OsMutex::new(Inner {
                threads: vec![TState::Runnable],
                cur: 0,
                mutex_owner: Vec::new(),
                n_condvars: 0,
                prefix,
                depth: 0,
                trace: Vec::new(),
                failure: None,
                handles: Vec::new(),
                max_threads,
            }),
            cv: OsCondvar::new(),
        }
    }
}

/// A participant's identity within a running schedule.
#[derive(Clone)]
pub(crate) struct Participant {
    pub(crate) shared: Arc<Shared>,
    pub(crate) id: usize,
}

thread_local! {
    static PARTICIPANT: RefCell<Option<Participant>> = RefCell::new(None);
}

/// The participant registration of the calling thread, if it is one.
pub(crate) fn current() -> Option<Participant> {
    PARTICIPANT.with(|p| p.borrow().clone())
}

fn locki(shared: &Shared) -> OsMutexGuard<'_, Inner> {
    // poison-tolerant: a participant that panicked while the scheduler
    // lock was held (impossible in normal operation) must not cascade.
    shared
        .inner
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Take the next choice at a branch with `n` options: replayed from the
/// prefix while it lasts, option 0 afterwards (DFS leftmost descent).
fn choice(inner: &mut Inner, n: usize) -> usize {
    let pick = if inner.depth < inner.prefix.len() {
        inner.prefix[inner.depth].min(n - 1)
    } else {
        0
    };
    inner.trace.push((pick as u32, n as u32));
    inner.depth += 1;
    pick
}

fn enabled(inner: &Inner) -> Vec<usize> {
    inner
        .threads
        .iter()
        .enumerate()
        .filter(|&(_, &st)| match st {
            TState::Runnable => true,
            TState::WantsLock(m) => inner.mutex_owner[m].is_none(),
            TState::Joining(c) => inner.threads[c] == TState::Done,
            TState::WaitingCv { .. } | TState::Done => false,
        })
        .map(|(t, _)| t)
        .collect()
}

/// Pick (and unblock) the next participant to run. Reports a deadlock
/// when nobody is enabled but the schedule has not finished.
fn schedule_next(inner: &mut Inner) {
    if inner.failure.is_some() {
        return;
    }
    let en = enabled(inner);
    if en.is_empty() {
        if inner.threads.iter().all(|&t| t == TState::Done) {
            return; // schedule complete
        }
        inner.failure = Some(format!(
            "deadlock: no participant is runnable (states: {:?}, \
             mutex owners: {:?})",
            inner.threads, inner.mutex_owner
        ));
        return;
    }
    let t = en[choice(inner, en.len())];
    match inner.threads[t] {
        TState::WantsLock(m) => {
            inner.mutex_owner[m] = Some(t);
            inner.threads[t] = TState::Runnable;
        }
        TState::Joining(_) => inner.threads[t] = TState::Runnable,
        TState::Runnable => {}
        TState::WaitingCv { .. } | TState::Done => {
            unreachable!("scheduled a blocked participant")
        }
    }
    inner.cur = t;
}

/// Apply `update` to the scheduler state, pass the token, and block
/// until this participant is scheduled again. The workhorse behind
/// every blocking sync operation.
pub(crate) fn yield_point(p: &Participant, update: impl FnOnce(&mut Inner)) {
    let mut inner = locki(&p.shared);
    update(&mut inner);
    schedule_next(&mut inner);
    p.shared.cv.notify_all();
    loop {
        if inner.failure.is_some() {
            drop(inner);
            p.shared.cv.notify_all();
            panic!("{ABORT_MSG}");
        }
        if inner.cur == p.id && inner.threads[p.id] == TState::Runnable {
            return;
        }
        inner = p
            .shared
            .cv
            .wait(inner)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
    }
}

/// Block until mutex `m` is granted to this participant.
pub(crate) fn acquire_mutex(p: &Participant, m: usize) {
    let id = p.id;
    yield_point(p, |inner| inner.threads[id] = TState::WantsLock(m));
}

/// Try to take mutex `m` without blocking; schedule point either way.
pub(crate) fn try_acquire_mutex(p: &Participant, m: usize) -> bool {
    yield_point(p, |_| {});
    let mut inner = locki(&p.shared);
    if inner.mutex_owner[m].is_none() {
        inner.mutex_owner[m] = Some(p.id);
        true
    } else {
        false
    }
}

/// Release mutex `m`. A schedule point in normal operation; during a
/// failed schedule or a panic unwind it only frees the ledger slot
/// (panicking inside `Drop` would abort the process).
pub(crate) fn release_mutex(p: &Participant, m: usize) {
    let mut inner = locki(&p.shared);
    if inner.mutex_owner[m] == Some(p.id) {
        inner.mutex_owner[m] = None;
    }
    if inner.failure.is_some() || std::thread::panicking() {
        drop(inner);
        p.shared.cv.notify_all();
        return;
    }
    schedule_next(&mut inner);
    p.shared.cv.notify_all();
    loop {
        if inner.failure.is_some() {
            drop(inner);
            p.shared.cv.notify_all();
            panic!("{ABORT_MSG}");
        }
        if inner.cur == p.id && inner.threads[p.id] == TState::Runnable {
            return;
        }
        inner = p
            .shared
            .cv
            .wait(inner)
            .unwrap_or_else(std::sync::PoisonError::into_inner);
    }
}

/// Register a model mutex; returns its ledger slot.
pub(crate) fn register_mutex(p: &Participant) -> usize {
    let mut inner = locki(&p.shared);
    inner.mutex_owner.push(None);
    inner.mutex_owner.len() - 1
}

/// Register a model condvar; returns its id.
pub(crate) fn register_condvar(p: &Participant) -> usize {
    let mut inner = locki(&p.shared);
    inner.n_condvars += 1;
    inner.n_condvars - 1
}

/// Park on condvar `cvid`, releasing mutex `m`; returns with `m`
/// re-acquired after a notify reaches this participant.
pub(crate) fn cv_wait(p: &Participant, cvid: usize, m: usize) {
    let id = p.id;
    yield_point(p, |inner| {
        debug_assert_eq!(inner.mutex_owner[m], Some(id), "cv wait without the lock");
        inner.mutex_owner[m] = None;
        inner.threads[id] = TState::WaitingCv { cv: cvid, mutex: m };
    });
}

/// Notify one (scheduler's choice — explored) or all waiters of
/// condvar `cvid`; each woken waiter re-contends for its mutex.
pub(crate) fn cv_notify(p: &Participant, cvid: usize, all: bool) {
    {
        let mut inner = locki(&p.shared);
        let waiters: Vec<usize> = inner
            .threads
            .iter()
            .enumerate()
            .filter(|&(_, &st)| matches!(st, TState::WaitingCv { cv, .. } if cv == cvid))
            .map(|(t, _)| t)
            .collect();
        let chosen: Vec<usize> = if waiters.is_empty() {
            Vec::new()
        } else if all {
            waiters
        } else {
            let pick = choice(&mut inner, waiters.len());
            vec![waiters[pick]]
        };
        for t in chosen {
            if let TState::WaitingCv { mutex, .. } = inner.threads[t] {
                inner.threads[t] = TState::WantsLock(mutex);
            }
        }
    }
    yield_point(p, |_| {});
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "participant panicked with a non-string payload".to_string()
    }
}

/// Body of every participant OS thread: register, wait for the first
/// turn, run the user closure with panic containment, then hand the
/// token on.
fn participant_main<F: FnOnce()>(p: Participant, f: F) {
    PARTICIPANT.with(|tl| *tl.borrow_mut() = Some(p.clone()));
    {
        let mut inner = locki(&p.shared);
        loop {
            if inner.failure.is_some() {
                // schedule already failed: never run the user closure
                inner.threads[p.id] = TState::Done;
                schedule_next(&mut inner);
                drop(inner);
                p.shared.cv.notify_all();
                return;
            }
            if inner.cur == p.id && inner.threads[p.id] == TState::Runnable {
                break;
            }
            inner = p
                .shared
                .cv
                .wait(inner)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
    let result = catch_unwind(AssertUnwindSafe(f));
    let mut inner = locki(&p.shared);
    for owner in inner.mutex_owner.iter_mut() {
        if *owner == Some(p.id) {
            *owner = None;
        }
    }
    inner.threads[p.id] = TState::Done;
    if let Err(payload) = result {
        let msg = panic_message(payload.as_ref());
        if msg != ABORT_MSG && inner.failure.is_none() {
            inner.failure = Some(msg);
        }
    }
    schedule_next(&mut inner);
    drop(inner);
    p.shared.cv.notify_all();
}

/// Handle to a participant spawned with [`spawn`]. Join happens at the
/// scheduler level; the OS thread itself is joined by the driver.
pub struct JoinHandle {
    id: usize,
}

impl JoinHandle {
    /// Block (as a schedule point) until the participant finishes.
    pub fn join(self) {
        let p = current()
            .unwrap_or_else(|| panic!("modelcheck::JoinHandle::join outside model()"));
        let id = self.id;
        let me = p.id;
        yield_point(&p, |inner| inner.threads[me] = TState::Joining(id));
    }
}

/// Spawn a participant thread inside a running model. Panics when
/// called outside [`model`].
pub fn spawn<F>(f: F) -> JoinHandle
where
    F: FnOnce() + Send + 'static,
{
    let p = current().unwrap_or_else(|| panic!("modelcheck::spawn outside model()"));
    let id;
    {
        let mut inner = locki(&p.shared);
        id = inner.threads.len();
        assert!(
            id < inner.max_threads,
            "model spawned more than {} threads",
            inner.max_threads
        );
        inner.threads.push(TState::Runnable);
        let child = Participant { shared: p.shared.clone(), id };
        let handle = std::thread::Builder::new()
            .name(format!("modelcheck-{id}"))
            .spawn(move || participant_main(child, f))
            .unwrap_or_else(|e| panic!("modelcheck participant spawn failed: {e}"));
        inner.handles.push(handle);
    }
    // schedule point: the child starting first is an explored ordering
    yield_point(&p, |_| {});
    JoinHandle { id }
}

/// Exploration bounds for [`model_with`].
#[derive(Clone, Copy, Debug)]
pub struct ModelOpts {
    /// Hard cap on explored schedules; exceeding it fails the model
    /// (shrink the model rather than raising the cap — exploration is
    /// only meaningful when it completes).
    pub max_schedules: usize,
    /// Hard cap on participants per schedule.
    pub max_threads: usize,
}

impl Default for ModelOpts {
    fn default() -> Self {
        ModelOpts {
            // `--cfg loom` runs get a deeper budget; either way the
            // exploration is exhaustive or the model fails loudly.
            max_schedules: if cfg!(loom) { 500_000 } else { 100_000 },
            max_threads: 8,
        }
    }
}

/// Run `f` under every schedule its sync operations allow (see the
/// module docs). Panics on the first schedule that fails, reporting
/// the failure and the choice sequence that reached it.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    model_with(ModelOpts::default(), f);
}

/// [`model`] with explicit exploration bounds.
pub fn model_with<F>(opts: ModelOpts, f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let mut prefix: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    loop {
        schedules += 1;
        assert!(
            schedules <= opts.max_schedules,
            "modelcheck: exceeded {} schedules — shrink the model",
            opts.max_schedules
        );
        let shared = Arc::new(Shared::new(prefix.clone(), opts.max_threads));
        let root = Participant { shared: shared.clone(), id: 0 };
        let f0 = Arc::clone(&f);
        let h0 = std::thread::Builder::new()
            .name("modelcheck-0".into())
            .spawn(move || participant_main(root, move || (*f0)()))
            .unwrap_or_else(|e| panic!("modelcheck root spawn failed: {e}"));
        let _ = h0.join();
        // children keep running after the root returns; drain until the
        // schedule has fully quiesced (spawn pushes while we pop)
        loop {
            let handle = locki(&shared).handles.pop();
            match handle {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
        let (failure, trace) = {
            let inner = locki(&shared);
            (inner.failure.clone(), inner.trace.clone())
        };
        if let Some(msg) = failure {
            let sched: Vec<u32> = trace.iter().map(|&(c, _)| c).collect();
            panic!(
                "modelcheck: schedule #{schedules} {sched:?} failed: {msg}"
            );
        }
        match next_prefix(&trace) {
            Some(next) => prefix = next,
            None => break, // leftmost-descent tree exhausted
        }
    }
}

/// Lexicographic successor of `trace` in the schedule tree: the longest
/// prefix whose last choice can be advanced. `None` when exploration
/// is complete.
fn next_prefix(trace: &[(u32, u32)]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        let (c, n) = trace[i];
        if c + 1 < n {
            let mut p: Vec<usize> =
                trace[..i].iter().map(|&(c, _)| c as usize).collect();
            p.push((c + 1) as usize);
            return Some(p);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::sync::{Condvar, Mutex};
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    /// Two increments under one lock session each: every interleaving
    /// must end at 2, and the critical sections must never overlap.
    #[test]
    fn mutual_exclusion_holds_in_every_schedule() {
        model(|| {
            let counter = Arc::new(Mutex::new(0i32));
            let in_crit = Arc::new(AtomicBool::new(false));
            let spawn_one = |counter: Arc<Mutex<i32>>, in_crit: Arc<AtomicBool>| {
                spawn(move || {
                    let mut g = counter.lock().unwrap();
                    assert!(
                        !in_crit.swap(true, Ordering::SeqCst),
                        "two participants inside the critical section"
                    );
                    *g += 1;
                    in_crit.store(false, Ordering::SeqCst);
                    drop(g);
                })
            };
            let h1 = spawn_one(counter.clone(), in_crit.clone());
            let h2 = spawn_one(counter.clone(), in_crit.clone());
            h1.join();
            h2.join();
            assert_eq!(*counter.lock().unwrap(), 2);
        });
    }

    /// A read-then-write race (two separate lock sessions) must be
    /// *found*: some schedule loses an update, some schedule doesn't.
    /// This is the canary that exploration actually branches.
    #[test]
    fn exploration_finds_a_seeded_lost_update() {
        let saw_lost = Arc::new(AtomicBool::new(false));
        let saw_both = Arc::new(AtomicBool::new(false));
        let (lost, both) = (saw_lost.clone(), saw_both.clone());
        model(move || {
            let cell = Arc::new(Mutex::new(0i32));
            let racer = |cell: Arc<Mutex<i32>>| {
                spawn(move || {
                    let read = *cell.lock().unwrap(); // session 1: read
                    *cell.lock().unwrap() = read + 1; // session 2: write
                })
            };
            let h1 = racer(cell.clone());
            let h2 = racer(cell.clone());
            h1.join();
            h2.join();
            match *cell.lock().unwrap() {
                1 => lost.store(true, Ordering::SeqCst),
                2 => both.store(true, Ordering::SeqCst),
                v => panic!("impossible final value {v}"),
            }
        });
        assert!(saw_lost.load(Ordering::SeqCst), "lost-update schedule never explored");
        assert!(saw_both.load(Ordering::SeqCst), "clean schedule never explored");
    }

    /// Opposite lock orders deadlock in some interleaving; the checker
    /// must report it rather than hang.
    #[test]
    fn deadlock_is_detected_and_reported() {
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            model(|| {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let h1 = {
                    let (a, b) = (a.clone(), b.clone());
                    spawn(move || {
                        let _ga = a.lock().unwrap();
                        let _gb = b.lock().unwrap();
                    })
                };
                let h2 = {
                    let (a, b) = (a.clone(), b.clone());
                    spawn(move || {
                        let _gb = b.lock().unwrap();
                        let _ga = a.lock().unwrap();
                    })
                };
                h1.join();
                h2.join();
            });
        }));
        let msg = panic_message(result.expect_err("AB/BA locks must deadlock somewhere").as_ref());
        assert!(msg.contains("deadlock"), "wrong failure: {msg}");
    }

    /// Producer/consumer over Mutex + Condvar: the consumer must see
    /// the value in every schedule, including notify-before-wait.
    #[test]
    fn condvar_handoff_never_loses_the_wakeup() {
        model(|| {
            let slot = Arc::new((Mutex::new(None::<i32>), Condvar::new()));
            let producer = {
                let slot = slot.clone();
                spawn(move || {
                    let (m, cv) = &*slot;
                    *m.lock().unwrap() = Some(42);
                    cv.notify_one();
                })
            };
            let (m, cv) = &*slot;
            let mut g = m.lock().unwrap();
            while g.is_none() {
                g = cv.wait(g).unwrap();
            }
            assert_eq!(*g, Some(42));
            drop(g);
            producer.join();
        });
    }

    /// An invariant violation reachable only through a specific
    /// interleaving must be reported with the failing schedule.
    #[test]
    fn interleaving_dependent_assertion_failure_is_caught() {
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            model(|| {
                let cell = Arc::new(Mutex::new(0i32));
                let racer = |cell: Arc<Mutex<i32>>| {
                    spawn(move || {
                        let read = *cell.lock().unwrap();
                        *cell.lock().unwrap() = read + 1;
                    })
                };
                let h1 = racer(cell.clone());
                let h2 = racer(cell.clone());
                h1.join();
                h2.join();
                // fails exactly on the lost-update interleavings
                assert_eq!(*cell.lock().unwrap(), 2, "lost update");
            });
        }));
        let msg = panic_message(result.expect_err("lost update must be found").as_ref());
        assert!(msg.contains("lost update"), "wrong failure: {msg}");
    }

    /// The same model explores the same number of schedules every time
    /// — determinism is what makes the DFS replay sound.
    #[test]
    fn exploration_is_deterministic() {
        let count = |out: Arc<AtomicUsize>| {
            model(move || {
                out.fetch_add(1, Ordering::SeqCst);
                let m = Arc::new(Mutex::new(0u32));
                let h = {
                    let m = m.clone();
                    spawn(move || *m.lock().unwrap() += 1)
                };
                *m.lock().unwrap() += 1;
                h.join();
            });
        };
        let a = Arc::new(AtomicUsize::new(0));
        let b = Arc::new(AtomicUsize::new(0));
        count(a.clone());
        count(b.clone());
        let (na, nb) = (a.load(Ordering::SeqCst), b.load(Ordering::SeqCst));
        assert_eq!(na, nb, "non-deterministic exploration");
        assert!(na > 1, "model with a race explored only one schedule");
    }
}
