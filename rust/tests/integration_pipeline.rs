//! End-to-end pipeline integration: dataset -> embedding -> quantizer ->
//! index -> search -> metrics, asserting the PAPER'S SHAPES (who wins,
//! in which direction) on CI-sized workloads.

use icq::bench::workload::{run_method, EmbedKind, RunSpec};
use icq::config::MethodKind;

fn spec(method: MethodKind, dataset: &str, k: usize) -> RunSpec {
    RunSpec {
        dataset: dataset.into(),
        n_database: 2500,
        n_queries: 60,
        method,
        embed: EmbedKind::Linear,
        d_embed: 16,
        k,
        m: 16,
        fast_k: 0,
        top_k: 10,
        seed: 0,
        fast_mode: true,
    }
}

#[test]
fn icq_is_cheaper_than_adc_baselines_at_equal_code_length() {
    // Fig. 1/2 shape: at the same (K, m), ICQ pays fewer table-adds per
    // candidate than any full-ADC method.
    let icq = run_method(&spec(MethodKind::Icq, "synthetic2", 8)).unwrap();
    let sq = run_method(&spec(MethodKind::Sq, "synthetic2", 8)).unwrap();
    assert_eq!(sq.avg_ops, 8.0, "ADC baseline must cost exactly K");
    assert!(
        icq.avg_ops < 0.85 * sq.avg_ops,
        "ICQ {} vs SQ {} ops",
        icq.avg_ops,
        sq.avg_ops
    );
    assert_eq!(icq.code_bits, sq.code_bits);
}

#[test]
fn icq_map_competitive_with_sq() {
    // Fig. 1/2 shape: at equal code length ICQ precision is at least
    // competitive (the paper shows it winning; we allow a small band on
    // CI-sized data).
    let icq = run_method(&spec(MethodKind::Icq, "synthetic1", 8)).unwrap();
    let sq = run_method(&spec(MethodKind::Sq, "synthetic1", 8)).unwrap();
    assert!(
        icq.map >= sq.map * 0.85,
        "ICQ MAP {} fell far below SQ MAP {}",
        icq.map,
        sq.map
    );
}

#[test]
fn ops_gap_grows_with_k() {
    // Fig. 3 (a)/(c) shape: the ICQ-vs-baseline cost gap widens as K grows.
    let icq4 = run_method(&spec(MethodKind::Icq, "synthetic2", 4)).unwrap();
    let icq8 = run_method(&spec(MethodKind::Icq, "synthetic2", 8)).unwrap();
    let gap4 = 4.0 - icq4.avg_ops;
    let gap8 = 8.0 - icq8.avg_ops;
    assert!(
        gap8 > gap4,
        "gap should widen with K: K=4 gap {gap4:.2}, K=8 gap {gap8:.2}"
    );
}

#[test]
fn map_improves_with_more_quantizers() {
    // Fig. 3 (b)/(d) shape: more quantizers -> lower quantization error ->
    // better retrieval, for both methods.
    let icq2 = run_method(&spec(MethodKind::Icq, "synthetic1", 2)).unwrap();
    let icq8 = run_method(&spec(MethodKind::Icq, "synthetic1", 8)).unwrap();
    assert!(
        icq8.map >= icq2.map * 0.95,
        "MAP should not degrade with K: K=2 {} K=8 {}",
        icq2.map,
        icq8.map
    );
}

#[test]
fn k2_disables_crude_path() {
    // Fig. 3 discussion: at K=2 both books span the space, so ICQ skips
    // crude estimation and costs exactly K like the baseline.
    let mut s = spec(MethodKind::Icq, "synthetic2", 2);
    s.fast_k = 2;
    let r = run_method(&s).unwrap();
    // cost == K exactly; with fast_k == K the "refine" step adds nothing,
    // so only candidates that improve the list register as refined.
    assert_eq!(r.avg_ops, 2.0);
}

#[test]
fn pq_and_opq_run_end_to_end() {
    let pq = run_method(&spec(MethodKind::Pq, "synthetic3", 4)).unwrap();
    assert!(pq.map > 0.0 && pq.avg_ops == 4.0);
    let opq = run_method(&spec(MethodKind::Opq, "synthetic3", 4)).unwrap();
    assert!(opq.map > 0.0);
}

#[test]
fn realworld_like_datasets_run_end_to_end() {
    let mut s = spec(MethodKind::Icq, "mnist", 4);
    s.n_database = 600;
    s.n_queries = 40;
    s.d_embed = 24;
    let r = run_method(&s).unwrap();
    assert!(r.map > 0.1, "mnist-like MAP {}", r.map);
    assert!(r.avg_ops < 4.0);
}

#[test]
fn nonlinear_embed_pipeline_runs() {
    let mut s = spec(MethodKind::Icq, "synthetic2", 4);
    s.embed = EmbedKind::Nonlinear;
    let r = run_method(&s).unwrap();
    assert!(r.map > 0.0);
}
