//! Golden-schema suite for the three gauntlet artifacts
//! (`BENCH_recall.json`, `BENCH_serving.json`, `BENCH_kernels.json`).
//!
//! Pins three contracts:
//!
//! * **round-trip** — every artifact the gauntlet emits survives
//!   parse(serialize(x)) == x through the in-tree JSON;
//! * **required keys** — the top-level header and every row carry the
//!   keys named by the `*_ROW_KEYS` constants (`cargo xtask
//!   bench-check` gates on these, so dropping one is an API break);
//! * **version-bump detection** — the `schema_version` inside the
//!   *committed* repo-root baselines must equal the in-code constants.
//!   Bumping a constant without regenerating (and re-reviewing) the
//!   committed artifacts fails here, and regenerating with a new
//!   version without bumping the constant fails too.

use std::sync::OnceLock;

use icq::core::json::Json;
use icq::eval::gauntlet::{
    self, GauntletReport, KERNELS_ROW_KEYS, KERNELS_SCHEMA_VERSION,
    RECALL_ROW_KEYS, RECALL_SCHEMA_VERSION, SERVING_ROW_KEYS,
    SERVING_SCHEMA_VERSION,
};

/// One smoke-profile run shared by every test in this binary (the
/// gauntlet is deterministic, so sharing loses nothing).
fn report() -> &'static GauntletReport {
    static REPORT: OnceLock<GauntletReport> = OnceLock::new();
    REPORT.get_or_init(|| {
        let p = gauntlet::profile_by_name("smoke").unwrap();
        let data = gauntlet::load_data(&p, None, None, None).unwrap();
        gauntlet::run(&p, &data).unwrap()
    })
}

/// Top-level keys common to all three artifacts.
const HEADER_KEYS: &[&str] = &[
    "bench",
    "schema_version",
    "profile",
    "seeded",
    "source",
    "n",
    "nq",
    "d",
    "k",
    "m",
    "rows",
];

fn assert_keys(j: &Json, keys: &[&str], what: &str) {
    for key in keys {
        assert!(
            j.get(key).is_some(),
            "{what}: required key '{key}' is missing"
        );
    }
}

fn assert_artifact_shape(
    j: &Json,
    bench: &str,
    version: f64,
    row_keys: &[&str],
) {
    assert_keys(j, HEADER_KEYS, bench);
    assert_eq!(j.get("bench").and_then(Json::as_str), Some(bench));
    assert_eq!(
        j.get("schema_version").and_then(Json::as_f64),
        Some(version),
        "{bench}: schema_version drifted from the in-code constant"
    );
    let rows = j.get("rows").and_then(Json::as_arr).unwrap();
    assert!(!rows.is_empty(), "{bench}: artifact has no rows");
    for row in rows {
        let id = row.get("id").and_then(Json::as_str).unwrap_or("<no id>");
        assert_keys(row, row_keys, &format!("{bench} row '{id}'"));
    }
}

#[test]
fn generated_artifacts_round_trip_through_json() {
    let r = report();
    for (name, j) in [
        ("recall", &r.recall),
        ("serving", &r.serving),
        ("kernels", &r.kernels),
    ] {
        let text = j.to_string_json();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("BENCH_{name} reparse failed: {e}"));
        assert_eq!(&back, j, "BENCH_{name} changed across a round-trip");
    }
}

#[test]
fn generated_artifacts_carry_required_keys() {
    let r = report();
    assert_artifact_shape(
        &r.recall,
        "gauntlet_recall",
        RECALL_SCHEMA_VERSION,
        RECALL_ROW_KEYS,
    );
    assert_keys(&r.recall, &["ncells", "top_k"], "gauntlet_recall extras");
    assert_artifact_shape(
        &r.serving,
        "gauntlet_serving",
        SERVING_SCHEMA_VERSION,
        SERVING_ROW_KEYS,
    );
    assert_keys(&r.serving, &["top_k"], "gauntlet_serving extras");
    assert_artifact_shape(
        &r.kernels,
        "gauntlet_kernels",
        KERNELS_SCHEMA_VERSION,
        KERNELS_ROW_KEYS,
    );
}

/// The serving artifact's cold-start columns: every row carries
/// numeric, non-negative `load_ms` / `peak_rss_bytes`, and the
/// `serving/flat_mapped` row (the zero-copy icqfmt2 open) exists with
/// a real measured load time next to `serving/flat`'s owned
/// deserialization — the pair that documents what the mapped format
/// buys at startup.
#[test]
fn serving_rows_carry_cold_start_metrics() {
    let r = report();
    let rows = r.serving.get("rows").and_then(Json::as_arr).unwrap();
    let mut ids = Vec::new();
    for row in rows {
        let id = row.get("id").and_then(Json::as_str).unwrap();
        ids.push(id.to_string());
        for field in ["load_ms", "peak_rss_bytes"] {
            let v = row
                .get(field)
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("row '{id}': {field} not numeric"));
            assert!(
                v >= 0.0 && v.is_finite(),
                "row '{id}': {field} = {v} is not a sane measurement"
            );
        }
    }
    for id in ["serving/flat", "serving/flat_mapped"] {
        assert!(ids.iter().any(|i| i == id), "missing row '{id}'");
    }
    let load_of = |id: &str| {
        rows.iter()
            .find(|r| r.get("id").and_then(Json::as_str) == Some(id))
            .and_then(|r| r.get("load_ms"))
            .and_then(Json::as_f64)
            .unwrap()
    };
    // both load paths were actually measured (min-of-5 of a real file
    // open can be fast, but never exactly zero)
    assert!(load_of("serving/flat") > 0.0, "owned load was not measured");
    assert!(
        load_of("serving/flat_mapped") > 0.0,
        "mapped open was not measured"
    );
}

/// Distinct row ids: duplicated ids would let bench-check silently
/// compare the wrong rows.
#[test]
fn generated_row_ids_are_unique() {
    let r = report();
    for (name, j) in [
        ("recall", &r.recall),
        ("serving", &r.serving),
        ("kernels", &r.kernels),
    ] {
        let mut seen = std::collections::HashSet::new();
        for row in j.get("rows").and_then(Json::as_arr).unwrap() {
            let id = row.get("id").and_then(Json::as_str).unwrap();
            assert!(seen.insert(id.to_string()), "BENCH_{name}: dup id {id}");
        }
    }
}

/// The committed repo-root baselines: parse, round-trip, required keys,
/// and schema-version agreement with the in-code constants (the
/// version-bump tripwire described in the module docs).
#[test]
fn committed_baselines_match_schema_constants() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .to_path_buf();
    for (file, bench, version, row_keys) in [
        (
            "BENCH_recall.json",
            "gauntlet_recall",
            RECALL_SCHEMA_VERSION,
            RECALL_ROW_KEYS,
        ),
        (
            "BENCH_serving.json",
            "gauntlet_serving",
            SERVING_SCHEMA_VERSION,
            SERVING_ROW_KEYS,
        ),
        (
            "BENCH_kernels.json",
            "gauntlet_kernels",
            KERNELS_SCHEMA_VERSION,
            KERNELS_ROW_KEYS,
        ),
    ] {
        let path = root.join(file);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let j = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{file} does not parse: {e}"));
        assert_artifact_shape(&j, bench, version, row_keys);
        let back = Json::parse(&j.to_string_json()).unwrap();
        assert_eq!(back, j, "{file} changed across a round-trip");
        assert_eq!(
            j.get("profile").and_then(Json::as_str),
            Some("fast"),
            "{file}: committed baseline must be the CI fast profile"
        );
    }
}

/// The smoke profile run used here and the committed fast baselines
/// must agree on the *set* of serving and kernel row ids (they are
/// profile-independent); recall rows differ only in the numeric
/// operating points, so compare the id shape `family/mode/...`.
#[test]
fn committed_baseline_row_families_match_generated() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap()
        .to_path_buf();
    let committed =
        Json::parse(&std::fs::read_to_string(root.join("BENCH_recall.json")).unwrap())
            .unwrap();
    let families = |j: &Json| -> std::collections::BTreeSet<String> {
        j.get("rows")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|r| {
                r.get("method").and_then(Json::as_str).unwrap().to_string()
            })
            .collect()
    };
    assert_eq!(
        families(&committed),
        families(&report().recall),
        "committed BENCH_recall.json covers different quantizer families \
         than the gauntlet emits"
    );
}
