//! IVF coarse-partition parity suite.
//!
//! The non-exhaustive layer is only trustworthy if it degrades to the
//! exhaustive scan *exactly*: with `nprobe == ncells` every cell is
//! probed, the cells regroup the flat index's own codes (partition
//! mode never re-encodes), one shared LUT computes the same f32
//! distances, and the per-cell ascending global-id maps keep the
//! canonical `(distance, id)` order — so the merged top-k must be
//! bitwise equal to the flat scan. This suite pins that across every
//! quantizer family (ICQ / PQ / OPQ / CQ / SQ), tail blocks, empty
//! cells, and `k` larger than any cell; pins recall@10 against the
//! flat quantized ranking as monotonically non-decreasing in `nprobe`
//! (probed cell sets are nested, so a flat-top-10 row once probed can
//! never be displaced); and pins the cell-granular sharded gather and
//! the snapshot round-trip to the single-process IVF result.

use std::sync::Arc;

use icq::config::SearchConfig;
use icq::coordinator::{
    BatchSearcher, IvfSearcher, LocalIvfShardBackend, ShardBackend,
    ShardedSearcher,
};
use icq::core::{Hit, Matrix, Rng};
use icq::data::Dataset;
use icq::index::ivf::{load_index, AnyIndex};
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::{EncodedIndex, IvfBuildOpts, IvfIndex, OpCounter};
use icq::quantizer::cq::{Cq, CqOpts};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::opq::{Opq, OpqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use icq::quantizer::sq::{Sq, SqOpts};

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

fn queries(nq: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

/// Build one index per quantizer family over the same kind of data.
/// Returns `(name, index, vectors)` — `vectors` live in the index's own
/// coordinate space (embedded for SQ), which is what the coarse
/// quantizer partitions.
fn method_indexes(
    n: usize,
    seed: u64,
) -> Vec<(&'static str, EncodedIndex, Matrix)> {
    let x = hetero(n, 16, seed);
    let labels: Vec<i32> = (0..n).map(|i| i as i32).collect();
    let mut out = Vec::new();

    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed },
    );
    out.push(("icq", EncodedIndex::build_icq(&icq, &x, labels.clone()), x.clone()));

    let pq = Pq::train(&x, PqOpts { k: 4, m: 16, iters: 4, seed });
    out.push(("pq", EncodedIndex::build(&pq, &x, labels.clone()), x.clone()));

    let opq = Opq::train(
        &x,
        OpqOpts { pq: PqOpts { k: 4, m: 16, iters: 4, seed }, outer_iters: 2 },
    );
    let mut opq_idx = EncodedIndex::build(&opq, &x, labels.clone());
    opq_idx.sigma = 0.0;
    out.push(("opq", opq_idx, x.clone()));

    let cq = Cq::train(
        &x,
        CqOpts { k: 4, m: 16, iters: 3, icm_sweeps: 2, seed },
    );
    out.push(("cq", EncodedIndex::build(&cq, &x, labels.clone()), x.clone()));

    // SQ: supervised projection + CQ; the index lives in the embedded
    // space, so the coarse partition runs on the embedded vectors.
    let y: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
    let sq = Sq::train(
        &Dataset::new(x.clone(), y),
        SqOpts {
            d_out: 8,
            cq: CqOpts { k: 4, m: 16, iters: 3, icm_sweeps: 2, seed },
            ridge: 1e-3,
        },
    );
    let emb = sq.embed(&x);
    out.push(("sq", EncodedIndex::build(&sq, &x, labels), emb));
    out
}

/// Flat exhaustive baseline: the per-query two-step scan over the
/// un-partitioned index (the path the IVF full probe must reproduce).
fn flat_topk(index: &EncodedIndex, qs: &Matrix, k: usize) -> Vec<Vec<Hit>> {
    let ops = OpCounter::new();
    let mut scratch = Vec::new();
    (0..qs.rows())
        .map(|qi| {
            search_icq::search_scanfirst_query_qlut(
                index,
                qs.row(qi),
                IcqSearchOpts { k, margin_scale: 1.0 },
                &ops,
                &mut scratch,
            )
        })
        .collect()
}

/// nprobe == ncells must be bitwise-identical to the flat scan for
/// every quantizer family — including tail blocks (n = 330 is not a
/// multiple of the 64-row code block).
#[test]
fn full_probe_is_bitwise_flat_for_every_method() {
    for (name, index, x) in method_indexes(330, 1) {
        let qs = queries(5, x.cols(), 2);
        let ivf = IvfIndex::partition(
            &index,
            &x,
            IvfBuildOpts { ncells: 7, iters: 6, seed: 0 },
        )
        .unwrap();
        let flat = flat_topk(&index, &qs, 10);
        let ops = OpCounter::new();
        for qi in 0..qs.rows() {
            let got = ivf.search(
                qs.row(qi),
                ivf.ncells(),
                IcqSearchOpts { k: 10, margin_scale: 1.0 },
                &ops,
            );
            assert_eq!(
                got, flat[qi],
                "{name}: query {qi} full-probe IVF != flat"
            );
        }
    }
}

/// Parity must survive k larger than every cell (each cell contributes
/// everything it has) and k larger than the database.
#[test]
fn full_probe_parity_when_k_exceeds_cell_size() {
    let (_, index, x) = method_indexes(150, 3).swap_remove(0);
    let qs = queries(3, 16, 4);
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 6, iters: 6, seed: 0 },
    )
    .unwrap();
    let ops = OpCounter::new();
    for k in [100usize, 500] {
        let flat = flat_topk(&index, &qs, k);
        for qi in 0..qs.rows() {
            let got = ivf.search(
                qs.row(qi),
                ivf.ncells(),
                IcqSearchOpts { k, margin_scale: 1.0 },
                &ops,
            );
            assert_eq!(got, flat[qi], "k={k} query {qi}");
        }
    }
    let all = flat_topk(&index, &qs, 500);
    assert_eq!(all[0].len(), 150, "k > n must return the whole database");
}

/// Duplicate-heavy data leaves most cells empty (two distinct points
/// cannot feed six centroids); empty cells must be skipped cleanly and
/// the full probe must still equal flat.
#[test]
fn full_probe_parity_with_empty_cells() {
    let a: Vec<f32> = (0..16).map(|j| j as f32 * 0.3).collect();
    let b: Vec<f32> = (0..16).map(|j| 5.0 - j as f32 * 0.2).collect();
    let x = Matrix::from_fn(60, 16, |i, j| {
        if i % 2 == 0 { a[j] } else { b[j] }
    });
    let pq = Pq::train(&x, PqOpts { k: 4, m: 16, iters: 4, seed: 0 });
    let index =
        EncodedIndex::build(&pq, &x, (0..60).map(|i| i as i32).collect());
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 6, iters: 8, seed: 0 },
    )
    .unwrap();
    let empties = (0..ivf.ncells())
        .filter(|&c| ivf.cell(c).unwrap().index.is_empty())
        .count();
    assert!(empties >= 4, "expected >= 4 empty cells, got {empties}");
    let qs = queries(4, 16, 5);
    let flat = flat_topk(&index, &qs, 12);
    let ops = OpCounter::new();
    for qi in 0..qs.rows() {
        let got = ivf.search(
            qs.row(qi),
            ivf.ncells(),
            IcqSearchOpts { k: 12, margin_scale: 1.0 },
            &ops,
        );
        assert_eq!(got, flat[qi], "query {qi} with empty cells");
    }
}

/// recall@10 against the flat *quantized* top-10 must be monotonically
/// non-decreasing in nprobe, reaching exactly 1.0 at the full probe.
/// (Probed-cell sets are nested in nprobe and a flat-top-10 row, once
/// probed, is beaten by at most 9 rows anywhere — so it stays ranked.)
#[test]
fn recall_at_10_is_monotone_in_nprobe() {
    let x = hetero(600, 16, 7);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed: 7 },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..600).map(|i| i as i32).collect());
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 16, iters: 8, seed: 0 },
    )
    .unwrap();
    let qs = queries(8, 16, 8);
    let oracle = flat_topk(&index, &qs, 10);
    let ops = OpCounter::new();
    let mut prev = -1.0f64;
    for nprobe in [1usize, 2, 4, 8, 16] {
        let mut hit_count = 0usize;
        for qi in 0..qs.rows() {
            let got = ivf.search(
                qs.row(qi),
                nprobe,
                IcqSearchOpts { k: 10, margin_scale: 1.0 },
                &ops,
            );
            let ids: std::collections::HashSet<u32> =
                got.iter().map(|h| h.id).collect();
            hit_count +=
                oracle[qi].iter().filter(|h| ids.contains(&h.id)).count();
        }
        let recall = hit_count as f64 / (qs.rows() * 10) as f64;
        assert!(
            recall >= prev,
            "recall@10 dropped from {prev} to {recall} at nprobe {nprobe}"
        );
        prev = recall;
        if nprobe == 16 {
            assert_eq!(recall, 1.0, "full probe must recover the flat top-10");
        }
    }
}

/// Cell-granular shards served through the scatter-gather must equal
/// the single-process IVF search — for partial probes too, because
/// every shard ranks the same shared centroid table.
#[test]
fn ivf_sharded_gather_equals_ivf_flat() {
    let x = hetero(400, 16, 9);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed: 9 },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..400).map(|i| i as i32).collect());
    let ivf = Arc::new(
        IvfIndex::partition(
            &index,
            &x,
            IvfBuildOpts { ncells: 9, iters: 6, seed: 0 },
        )
        .unwrap(),
    );
    let qs = queries(6, 16, 10);
    for nprobe in [1usize, 3, 9] {
        let searcher =
            IvfSearcher::new(ivf.clone(), nprobe, SearchConfig::default());
        let flat = searcher.search_batch(&qs, 10).unwrap();
        for n_shards in [2usize, 4] {
            let ops = Arc::new(OpCounter::new());
            let backends: Vec<Box<dyn ShardBackend>> = ivf
                .split_cells(n_shards)
                .unwrap()
                .into_iter()
                .map(|shard| {
                    Box::new(LocalIvfShardBackend::new(
                        Arc::new(shard),
                        nprobe,
                        SearchConfig::default(),
                        ops.clone(),
                    )) as Box<dyn ShardBackend>
                })
                .collect();
            let gather =
                ShardedSearcher::from_backends(backends, None, 16, ops)
                    .unwrap();
            let got = gather.search_batch(&qs, 10).unwrap();
            assert_eq!(
                got, flat,
                "nprobe {nprobe} x {n_shards} shards diverged from flat IVF"
            );
        }
    }
}

/// Snapshot round-trip through a real file: the reloaded index (via the
/// version-dispatching loader) must search bitwise-identically, and the
/// same loader must hand a plain flat snapshot back as flat.
#[test]
fn snapshot_roundtrip_through_file_is_bitwise() {
    let x = hetero(260, 16, 11);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed: 11 },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..260).map(|i| i as i32).collect());
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 5, iters: 6, seed: 0 },
    )
    .unwrap();
    let dir = std::env::temp_dir().join("icq_ivf_parity_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ivf.icqf");
    ivf.to_pack().save(&path).unwrap();
    let pack = icq::data::format::TensorPack::load(&path).unwrap();
    let AnyIndex::Ivf(back) = load_index(&pack).unwrap() else {
        panic!("IVF snapshot loaded as flat");
    };
    let qs = queries(5, 16, 12);
    let ops = OpCounter::new();
    for nprobe in [2usize, 5] {
        for qi in 0..qs.rows() {
            let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
            assert_eq!(
                back.search(qs.row(qi), nprobe, opts, &ops),
                ivf.search(qs.row(qi), nprobe, opts, &ops),
                "nprobe {nprobe} query {qi} changed across the round-trip"
            );
        }
    }
    // flat snapshots still load as flat through the same entry point
    let flat_path = dir.join("flat.icqf");
    index.to_pack().save(&flat_path).unwrap();
    let flat_pack = icq::data::format::TensorPack::load(&flat_path).unwrap();
    match load_index(&flat_pack).unwrap() {
        AnyIndex::Flat(f) => assert_eq!(f.len(), index.len()),
        AnyIndex::Ivf(_) => panic!("flat snapshot loaded as IVF"),
    }
}
