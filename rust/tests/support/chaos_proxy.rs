//! Deterministic fault-injecting TCP proxy for the chaos suite.
//!
//! Sits between a wire-protocol client and a real shard server and
//! applies a scripted fault per server→client frame (the hello is
//! frame 0), so tests trigger "the reply never came", "the connection
//! died mid-frame", or "a byte flipped in flight" exactly when they
//! mean to — no sleeps-and-prayers timing. The client→server direction
//! is pumped through untouched.
//!
//! Scripts are consumed per accepted connection in order; once the
//! scripts run out, further connections pass everything through
//! (letting recovery paths — probes, redials — succeed on purpose).

#![allow(dead_code)] // each test crate uses the subset it needs

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One scripted action applied to the n-th server→client frame of a
/// proxied connection. Entries past the script's end are `Pass`.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Forward the frame untouched.
    Pass,
    /// Forward the frame after a fixed delay.
    Delay(Duration),
    /// Swallow this frame and every later one; the connection stays
    /// open (a peer that accepted work and will never answer).
    BlackHole,
    /// Close both directions before forwarding this frame.
    Disconnect,
    /// Forward only the first `n` bytes of this frame, then close.
    TruncateAfter(usize),
    /// Flip one payload bit, then forward (the checksum now lies).
    CorruptBit,
}

/// A fault-injecting TCP proxy in front of one upstream address.
pub struct ChaosProxy {
    addr: String,
    accepted: Arc<AtomicUsize>,
}

impl ChaosProxy {
    /// Bind an ephemeral loopback port and start proxying to
    /// `upstream`. Connection `i` (in accept order) runs `scripts[i]`;
    /// connections past the end of `scripts` pass everything through.
    pub fn spawn(upstream: String, scripts: Vec<Vec<Fault>>) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accepted = Arc::new(AtomicUsize::new(0));
        let counter = accepted.clone();
        let scripts: Arc<Mutex<VecDeque<Vec<Fault>>>> =
            Arc::new(Mutex::new(scripts.into_iter().collect()));
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(client) = stream else { break };
                counter.fetch_add(1, Ordering::SeqCst);
                let script =
                    scripts.lock().unwrap().pop_front().unwrap_or_default();
                let upstream = upstream.clone();
                std::thread::spawn(move || {
                    proxy_conn(client, &upstream, script)
                });
            }
        });
        ChaosProxy { addr, accepted }
    }

    /// The proxy's dialable "host:port".
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connections accepted so far (for asserting dial/redial counts).
    pub fn accepted(&self) -> usize {
        self.accepted.load(Ordering::SeqCst)
    }
}

fn proxy_conn(client: TcpStream, upstream: &str, script: Vec<Fault>) {
    let Ok(server) = TcpStream::connect(upstream) else {
        let _ = client.shutdown(Shutdown::Both);
        return;
    };
    client.set_nodelay(true).ok();
    server.set_nodelay(true).ok();
    // client -> server: raw byte pump on its own thread
    let (c_read, s_write) =
        (client.try_clone().unwrap(), server.try_clone().unwrap());
    let c2s = std::thread::spawn(move || pump_raw(c_read, s_write));
    // server -> client: frame-aware, scripted
    pump_frames(server, &client, &script);
    let _ = client.shutdown(Shutdown::Both);
    let _ = c2s.join();
}

fn pump_raw(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 4096];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Write);
}

/// Read one whole wire frame (11-byte header + payload + 4-byte CRC).
fn read_whole_frame(from: &mut TcpStream) -> Option<Vec<u8>> {
    let mut header = [0u8; 11];
    from.read_exact(&mut header).ok()?;
    let len =
        u32::from_le_bytes([header[7], header[8], header[9], header[10]])
            as usize;
    let mut frame = vec![0u8; 11 + len + 4];
    frame[..11].copy_from_slice(&header);
    from.read_exact(&mut frame[11..]).ok()?;
    Some(frame)
}

fn pump_frames(mut server: TcpStream, client: &TcpStream, script: &[Fault]) {
    // `Write` is implemented for `&TcpStream`; a mutable binding to the
    // shared reference is all we need to write to the client half
    let mut out = client;
    let mut blackholed = false;
    let mut frame_idx = 0usize;
    loop {
        let Some(mut frame) = read_whole_frame(&mut server) else {
            // upstream closed: mirror it to the client
            return;
        };
        let fault = script.get(frame_idx).copied().unwrap_or(Fault::Pass);
        frame_idx += 1;
        if blackholed {
            // keep draining upstream so its writer never wedges, but
            // nothing reaches the client anymore
            continue;
        }
        match fault {
            Fault::Pass => {
                if out.write_all(&frame).is_err() {
                    return;
                }
            }
            Fault::Delay(d) => {
                std::thread::sleep(d);
                if out.write_all(&frame).is_err() {
                    return;
                }
            }
            Fault::BlackHole => {
                blackholed = true;
            }
            Fault::Disconnect => {
                let _ = server.shutdown(Shutdown::Both);
                return;
            }
            Fault::TruncateAfter(n) => {
                let n = n.min(frame.len());
                let _ = out.write_all(&frame[..n]);
                let _ = server.shutdown(Shutdown::Both);
                return;
            }
            Fault::CorruptBit => {
                // flip inside the payload when there is one, else in
                // the CRC — either way the checksum check must trip
                let off = if frame.len() > 15 { 11 } else { frame.len() - 1 };
                frame[off] ^= 0x04;
                if out.write_all(&frame).is_err() {
                    return;
                }
            }
        }
    }
}
