//! Shared test-support modules (not a test crate by itself: cargo only
//! builds top-level files in `tests/` as test binaries).

pub mod chaos_proxy;
