//! Remote-shard serving suite: the mixed local+remote scatter-gather
//! must be bitwise identical to the flat single-process path across the
//! whole quantizer zoo (PQ / OPQ / CQ / SQ / ICQ), tied distances, and
//! k > shard size — and every remote failure mode (dead shard at
//! connect, mid-stream disconnect, truncated/corrupt frame, version
//! mismatch) must surface as a structured error: no hang, no silent
//! partial top-k.
//!
//! Servers here are in-process threads running the real
//! [`wire::serve_shard`] accept loop over real loopback TCP sockets —
//! the same code path `icq shard-server` runs (the multi-process flavor
//! is covered by `tests/multihost_loopback.rs`).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use icq::config::SearchConfig;
use icq::coordinator::wire::{
    self, Frame, HelloInfo, WireError, WIRE_MAGIC,
};
use icq::coordinator::{
    BatchSearcher, LocalShardBackend, NativeSearcher, RemoteShardBackend,
    ShardBackend, ShardedSearcher,
};
use icq::core::{Matrix, Metric, Rng};
use icq::data::Dataset;
use icq::index::shard::{ShardPolicy, ShardedIndex};
use icq::index::{EncodedIndex, OpCounter};
use icq::quantizer::cq::{Cq, CqOpts};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::opq::{Opq, OpqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use icq::quantizer::sq::{Sq, SqOpts};

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

fn queries(nq: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

/// Serve `index` (global start row `start`) on an ephemeral loopback
/// port from a detached thread; returns the address to dial.
fn spawn_server(index: EncodedIndex, start: usize) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = wire::serve_shard(listener, Arc::new(index), start);
    });
    addr
}

fn timeout() -> Duration {
    Duration::from_secs(10)
}

/// Cut `index` into 3 shards, serve shards 0 and 1 over loopback TCP,
/// keep shard 2 local, and assert the gather equals the flat batched
/// path exactly for every `top_k` given.
fn assert_mixed_parity(index: &EncodedIndex, qs: &Matrix, top_ks: &[usize]) {
    let sharded = ShardedIndex::build(index, ShardPolicy::Count(3)).unwrap();
    assert_eq!(sharded.num_shards(), 3, "index too small for 3 shards");
    let cfg = SearchConfig::default();
    let mut backends: Vec<Box<dyn ShardBackend>> = Vec::new();
    for s in [0usize, 1] {
        let addr =
            spawn_server(sharded.shard(s).as_ref().clone(), sharded.spec(s).start);
        let remote =
            RemoteShardBackend::connect_with_timeout(&addr, cfg, timeout())
                .unwrap();
        assert_eq!(remote.hello().start, sharded.spec(s).start);
        assert_eq!(remote.hello().shard_len, sharded.shard(s).len());
        backends.push(Box::new(remote));
    }
    let ops = Arc::new(OpCounter::new());
    backends.push(Box::new(LocalShardBackend::new(
        sharded.spec(2).start,
        sharded.shard(2).clone(),
        cfg,
        ops.clone(),
    )));
    let searcher = ShardedSearcher::from_backends(
        backends,
        Some(sharded.shard(2).clone()),
        index.dim(),
        ops,
    )
    .unwrap();
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    for &top_k in top_ks {
        let got = searcher.search_batch(qs, top_k).unwrap();
        let want = flat.search_batch(qs, top_k).unwrap();
        assert_eq!(got.len(), want.len());
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g, w,
                "top_k={top_k} query {qi}: mixed local+remote gather \
                 diverged from flat"
            );
        }
    }
}

#[test]
fn mixed_gather_matches_flat_icq_with_ties_and_large_k() {
    // duplicate every vector (i and i + 150 encode identically), so
    // equal distances appear across shard boundaries and the merge's
    // (distance, id) tie-breaking is load-bearing
    let base = hetero(150, 16, 1);
    let x = Matrix::from_fn(300, 16, |i, j| base.get(i % 150, j));
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 6, prior_steps: 100, seed: 1 },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..300).map(|i| i as i32).collect());
    // top_k 40: ties guaranteed inside the list; top_k 200 > shard size
    assert_mixed_parity(&index, &queries(5, 16, 2), &[10, 40, 200]);
}

#[test]
fn mixed_gather_matches_flat_pq() {
    let x = hetero(260, 16, 3);
    let pq = Pq::train(&x, PqOpts { k: 4, m: 16, iters: 5, seed: 3 });
    let index =
        EncodedIndex::build(&pq, &x, (0..260).map(|i| i as i32).collect());
    assert_mixed_parity(&index, &queries(4, 16, 4), &[8, 100]);
}

#[test]
fn mixed_gather_matches_flat_opq() {
    let x = hetero(260, 8, 5);
    let opq = Opq::train(
        &x,
        OpqOpts { pq: PqOpts { k: 4, m: 8, iters: 4, seed: 1 }, outer_iters: 2 },
    );
    let index =
        EncodedIndex::build(&opq, &x, (0..260).map(|i| i as i32).collect());
    assert_mixed_parity(&index, &queries(4, 8, 6), &[10]);
}

#[test]
fn mixed_gather_matches_flat_cq() {
    let x = hetero(260, 8, 7);
    let cq =
        Cq::train(&x, CqOpts { k: 3, m: 8, iters: 3, icm_sweeps: 1, seed: 2 });
    let index =
        EncodedIndex::build(&cq, &x, (0..260).map(|i| i as i32).collect());
    assert_mixed_parity(&index, &queries(4, 8, 8), &[10]);
}

#[test]
fn mixed_gather_matches_flat_sq() {
    let x = hetero(260, 10, 9);
    let y: Vec<i32> = (0..260).map(|i| (i % 3) as i32).collect();
    let data = Dataset::new(x, y.clone());
    let sq = Sq::train(
        &data,
        SqOpts {
            d_out: 6,
            cq: CqOpts { k: 2, m: 8, iters: 3, icm_sweeps: 1, seed: 3 },
            ridge: 1e-3,
        },
    );
    let index = EncodedIndex::build(&sq, &data.x, y);
    // the SQ index lives in the embedded space; queries must be embedded
    let qz = sq.embed(&queries(4, 10, 10));
    assert_mixed_parity(&index, &qz, &[10]);
}

fn small_icq_index(n: usize, seed: u64) -> EncodedIndex {
    let x = hetero(n, 16, seed);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed },
    );
    EncodedIndex::build_icq(&icq, &x, (0..n).map(|i| i as i32).collect())
}

// ---------------------------------------------------------------------
// failure modes
// ---------------------------------------------------------------------

/// Dead shard at connect: a port nobody listens on must produce a
/// structured connect error, not a hang.
#[test]
fn dead_shard_at_connect_is_a_structured_error() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // now definitely nothing is listening
    let err = RemoteShardBackend::connect_with_timeout(
        &addr,
        SearchConfig::default(),
        timeout(),
    )
    .unwrap_err();
    assert!(
        format!("{err:#}").contains("connecting to shard server"),
        "unexpected error: {err:#}"
    );
}

/// Mid-stream disconnect: the server dies after the hello; the next
/// search must fail with a structured wire error and the gather must
/// fail the whole batch, naming the backend.
#[test]
fn mid_stream_disconnect_fails_the_batch() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        // accept one connection, greet, read a bit, then hang up
        let (sock, _) = listener.accept().unwrap();
        let mut w = sock.try_clone().unwrap();
        wire::write_frame(
            &mut w,
            &Frame::Hello(HelloInfo {
                dim: 16,
                shard_len: 100,
                start: 0,
                fast_k: 2,
                metric: Metric::L2,
            }),
        )
        .unwrap();
        w.flush().unwrap();
        let mut buf = [0u8; 16];
        let _ = (&sock).read(&mut buf);
        // sock drops here: mid-exchange disconnect
    });
    let cfg = SearchConfig::default();
    let remote =
        RemoteShardBackend::connect_with_timeout(&addr, cfg, timeout())
            .unwrap();
    assert_eq!(remote.dim(), 16);

    let index = small_icq_index(120, 11);
    let ops = Arc::new(OpCounter::new());
    let idx = Arc::new(index);
    let backends: Vec<Box<dyn ShardBackend>> = vec![
        Box::new(LocalShardBackend::new(0, idx.clone(), cfg, ops.clone())),
        Box::new(remote),
    ];
    let searcher =
        ShardedSearcher::from_backends(backends, Some(idx), 16, ops).unwrap();
    let err = searcher
        .search_batch(&queries(2, 16, 12), 5)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&addr), "error does not name the shard: {msg}");
    assert!(
        msg.contains("failed the batch"),
        "gather did not fail the batch: {msg}"
    );
}

/// Spawn a server that greets every connection properly, then answers
/// any request with a corrupted (flipped payload byte) or truncated
/// results frame and hangs up. Every connection misbehaves the same
/// way, so the client's stale-connection redial cannot "fix" it.
/// (Deliberately not the chaos proxy from `tests/support`: this fakes
/// the *server's own* bytes with no real index behind it, while the
/// proxy injects faults in front of a healthy server.)
fn evil_reply_server(truncate: bool) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        loop {
            let Ok((sock, _)) = listener.accept() else { break };
            let mut w = sock.try_clone().unwrap();
            wire::write_frame(
                &mut w,
                &Frame::Hello(HelloInfo {
                    dim: 4,
                    shard_len: 10,
                    start: 0,
                    fast_k: 1,
                    metric: Metric::L2,
                }),
            )
            .unwrap();
            w.flush().unwrap();
            // wait for a request frame (read its header worth of bytes)
            let mut reader = sock.try_clone().unwrap();
            let mut hdr = [0u8; 11];
            if reader.read_exact(&mut hdr).is_err() {
                continue;
            }
            let len = u32::from_le_bytes([hdr[7], hdr[8], hdr[9], hdr[10]]);
            let mut rest = vec![0u8; len as usize + 4];
            let _ = reader.read_exact(&mut rest);
            let mut reply = Vec::new();
            wire::write_frame(&mut reply, &Frame::Results { hits: vec![vec![]] })
                .unwrap();
            if truncate {
                let _ = w.write_all(&reply[..reply.len() - 2]);
            } else {
                reply[12] ^= 0x10; // corrupt a payload byte
                let _ = w.write_all(&reply);
            }
            let _ = w.flush();
            // drop the socket: the client must not wait for more
        }
    });
    addr
}

/// Truncated and corrupt reply frames must surface as typed wire
/// errors (checksum / truncation), never as garbage results. A
/// truncated reply on a pooled connection is allowed one transparent
/// redial (the stale-socket path); a persistently evil server must
/// still surface the error after it.
#[test]
fn corrupt_and_truncated_frames_are_structured_errors() {
    let cfg = SearchConfig::default();
    let job_queries = Arc::new(Matrix::zeros(1, 4));
    for (truncate, expect) in [(false, "checksum"), (true, "mid-frame")] {
        let addr = evil_reply_server(truncate);
        let mut remote =
            RemoteShardBackend::connect_with_timeout(&addr, cfg, timeout())
                .unwrap();
        let err = remote
            .search(&icq::coordinator::ShardJob {
                queries: job_queries.clone(),
                luts: Arc::new(Vec::new()),
                top_k: 3,
                filter: None,
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains(expect) || msg.contains("closed"),
            "expected a '{expect}' wire error, got: {msg}"
        );
        let metrics = remote.endpoint().metrics();
        let redials =
            metrics.redials.load(std::sync::atomic::Ordering::Relaxed);
        if truncate {
            // mid-frame drop on the pooled connection earned exactly
            // one redial; the fresh connection's failure surfaced
            assert_eq!(redials, 1, "expected one transparent redial");
        } else {
            // checksum corruption is a protocol fault, never redialed
            assert_eq!(redials, 0, "corrupt frames must not be retried");
        }
    }
}

/// A server speaking a different protocol version must be rejected at
/// connect with a typed version-mismatch error.
#[test]
fn version_mismatch_is_rejected_at_connect() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (mut sock, _) = listener.accept().unwrap();
        // hand-build a v99 hello frame
        let payload = [0u8; 24];
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&99u16.to_le_bytes());
        frame.push(0);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        let mut sum = vec![0u8];
        sum.extend_from_slice(&payload);
        frame.extend_from_slice(&wire::crc32(&sum).to_le_bytes());
        sock.write_all(&frame).unwrap();
        sock.flush().unwrap();
        std::thread::sleep(Duration::from_millis(200));
    });
    let err = RemoteShardBackend::connect_with_timeout(
        &addr,
        SearchConfig::default(),
        timeout(),
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("version mismatch") && msg.contains("v99"),
        "expected a version mismatch, got: {msg}"
    );
    assert!(
        err.chain().any(|c| {
            matches!(
                c.downcast_ref::<WireError>(),
                Some(WireError::VersionMismatch { got: 99, .. })
            )
        }),
        "typed WireError not in the chain: {msg}"
    );
}

/// Server-side request validation: wrong dim and drifted fast_k get an
/// error frame (surfaced as a remote error), and the connection stays
/// usable for a following well-formed request.
#[test]
fn server_rejects_bad_requests_but_connection_survives() {
    let index = small_icq_index(130, 13);
    let fast_k = index.fast_k;
    let addr = spawn_server(index.clone(), 0);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = std::io::BufReader::new(stream);
    let hello = wire::read_frame(&mut r).unwrap();
    assert!(matches!(hello, Frame::Hello(h) if h.fast_k == fast_k));

    // wrong dimensionality
    wire::write_frame(
        &mut w,
        &Frame::Query {
            top_k: 3,
            fast_k,
            margin_scale: 1.0,
            metric: Metric::L2,
            queries: Matrix::zeros(1, 5),
            filter: None,
        },
    )
    .unwrap();
    w.flush().unwrap();
    match wire::read_frame(&mut r).unwrap() {
        Frame::Error { message } => {
            assert!(message.contains("dim"), "got: {message}")
        }
        f => panic!("expected an error frame, got {f:?}"),
    }

    // drifted fast_k
    wire::write_frame(
        &mut w,
        &Frame::Query {
            top_k: 3,
            fast_k: fast_k + 1,
            margin_scale: 1.0,
            metric: Metric::L2,
            queries: Matrix::zeros(1, 16),
            filter: None,
        },
    )
    .unwrap();
    w.flush().unwrap();
    match wire::read_frame(&mut r).unwrap() {
        Frame::Error { message } => {
            assert!(message.contains("fast_k"), "got: {message}")
        }
        f => panic!("expected an error frame, got {f:?}"),
    }

    // the connection still answers a good request
    wire::write_frame(
        &mut w,
        &Frame::Query {
            top_k: 4,
            fast_k,
            margin_scale: 1.0,
            metric: Metric::L2,
            queries: queries(2, 16, 14),
            filter: None,
        },
    )
    .unwrap();
    w.flush().unwrap();
    match wire::read_frame(&mut r).unwrap() {
        Frame::Results { hits } => {
            assert_eq!(hits.len(), 2);
            for per_query in &hits {
                assert_eq!(per_query.len(), 4);
                for win in per_query.windows(2) {
                    assert!(
                        win[0].dist < win[1].dist
                            || (win[0].dist == win[1].dist
                                && win[0].id < win[1].id),
                        "unordered hits"
                    );
                }
            }
        }
        f => panic!("expected results, got {f:?}"),
    }
}

/// A remote backend must recover after a failed exchange by redialing:
/// first server instance dies mid-stream, a healthy one takes over the
/// same address... which ephemeral ports cannot guarantee, so instead:
/// the backend's poisoned connection makes the *next* search fail fast
/// on reconnect (refused), still structured.
#[test]
fn poisoned_connection_redials_and_reports_refusal() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || {
        let (sock, _) = listener.accept().unwrap();
        let mut w = sock.try_clone().unwrap();
        wire::write_frame(
            &mut w,
            &Frame::Hello(HelloInfo {
                dim: 4,
                shard_len: 10,
                start: 0,
                fast_k: 1,
                metric: Metric::L2,
            }),
        )
        .unwrap();
        w.flush().unwrap();
        // die immediately: listener drops too, so redials are refused
    });
    let mut remote = RemoteShardBackend::connect_with_timeout(
        &addr,
        SearchConfig::default(),
        timeout(),
    )
    .unwrap();
    handle.join().unwrap();
    let job = icq::coordinator::ShardJob {
        queries: Arc::new(Matrix::zeros(1, 4)),
        luts: Arc::new(Vec::new()),
        top_k: 2,
        filter: None,
    };
    let first = remote.search(&job).unwrap_err();
    assert!(
        format!("{first:#}").contains(&addr),
        "first failure unnamed: {first:#}"
    );
    let second = remote.search(&job).unwrap_err();
    assert!(
        format!("{second:#}").contains("connecting to shard server"),
        "redial not attempted / not structured: {second:#}"
    );
}

/// Sanity: hits crossing the wire are genuinely global ids from the
/// served shard's range.
#[test]
fn remote_hits_arrive_in_global_id_space() {
    let index = small_icq_index(200, 15);
    let shard = index.slice(64, 200);
    let addr = spawn_server(shard, 64);
    let mut remote = RemoteShardBackend::connect_with_timeout(
        &addr,
        SearchConfig::default(),
        timeout(),
    )
    .unwrap();
    assert_eq!(remote.hello().start, 64);
    let res = remote
        .search(&icq::coordinator::ShardJob {
            queries: Arc::new(queries(3, 16, 16)),
            luts: Arc::new(Vec::new()),
            top_k: 6,
            filter: None,
        })
        .unwrap();
    assert_eq!(res.len(), 3);
    for hits in &res {
        assert_eq!(hits.len(), 6);
        for h in hits {
            assert!(
                (64..200).contains(&(h.id as usize)),
                "id {} outside the shard's global range",
                h.id
            );
        }
    }
}

/// Metric drift must never be silently served: a gateway configured
/// for a different similarity regime than the shard announces is
/// rejected at connect with a typed error, and a drifted per-query
/// metric tag gets an error frame naming the drift (the connection
/// survives for a corrected request, mirroring the fast_k checks).
#[test]
fn metric_drift_is_rejected_at_connect_and_per_query() {
    let index = small_icq_index(130, 21);
    let fast_k = index.fast_k;
    let addr = spawn_server(index, 0);

    // gateway thinks inner-product, shard serves l2: typed connect error
    let cfg = SearchConfig {
        metric: Metric::InnerProduct,
        ..SearchConfig::default()
    };
    let err = RemoteShardBackend::connect_with_timeout(&addr, cfg, timeout())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("config drift") && msg.contains("metric"),
        "connect did not surface the metric drift: {msg}"
    );

    // raw drifted query frame: error frame, and the connection survives
    let stream = TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = std::io::BufReader::new(stream);
    match wire::read_frame(&mut r).unwrap() {
        Frame::Hello(h) => assert_eq!(h.metric, Metric::L2),
        f => panic!("expected a hello, got {f:?}"),
    }
    wire::write_frame(
        &mut w,
        &Frame::Query {
            top_k: 3,
            fast_k,
            margin_scale: 1.0,
            metric: Metric::Cosine,
            queries: Matrix::zeros(1, 16),
            filter: None,
        },
    )
    .unwrap();
    w.flush().unwrap();
    match wire::read_frame(&mut r).unwrap() {
        Frame::Error { message } => assert!(
            message.contains("metric") && message.contains("config drift"),
            "got: {message}"
        ),
        f => panic!("expected an error frame, got {f:?}"),
    }
    wire::write_frame(
        &mut w,
        &Frame::Query {
            top_k: 3,
            fast_k,
            margin_scale: 1.0,
            metric: Metric::L2,
            queries: queries(1, 16, 22),
            filter: None,
        },
    )
    .unwrap();
    w.flush().unwrap();
    match wire::read_frame(&mut r).unwrap() {
        Frame::Results { hits } => assert_eq!(hits[0].len(), 3),
        f => panic!("expected results after the rejected frame, got {f:?}"),
    }
}

/// A job-level *global* filter is cut to the shard's row range before
/// it crosses the wire, and the remote filtered results are exactly the
/// unfiltered remote ranking restricted to allowed rows.
#[test]
fn remote_filtered_search_matches_post_filtered_scan() {
    use icq::index::RowFilter;
    let index = small_icq_index(200, 23);
    let shard = index.slice(64, 200);
    let addr = spawn_server(shard, 64);
    let mut remote = RemoteShardBackend::connect_with_timeout(
        &addr,
        SearchConfig::default(),
        timeout(),
    )
    .unwrap();
    let qs = Arc::new(queries(3, 16, 24));
    let unfiltered = remote
        .search(&icq::coordinator::ShardJob {
            queries: qs.clone(),
            luts: Arc::new(Vec::new()),
            top_k: 200,
            filter: None,
        })
        .unwrap();
    let allowed: Vec<usize> = (0..200).filter(|i| i % 3 == 0).collect();
    let filter = RowFilter::from_indices(200, &allowed);
    let got = remote
        .search(&icq::coordinator::ShardJob {
            queries: qs,
            luts: Arc::new(Vec::new()),
            top_k: 6,
            filter: Some(Arc::new(filter.clone())),
        })
        .unwrap();
    for (qi, (g, u)) in got.iter().zip(&unfiltered).enumerate() {
        let want: Vec<_> = u
            .iter()
            .filter(|h| filter.allows(h.id as usize))
            .take(6)
            .cloned()
            .collect();
        assert_eq!(g, &want, "query {qi}: remote filtered != post-filtered");
    }
}
