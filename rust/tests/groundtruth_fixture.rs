//! Brute-force ground-truth generator vs the committed TexMex fixtures.
//!
//! `tiny_gt.ivecs` holds the hand-computed exact neighbor lists of the
//! `tiny.bvecs` queries against the `tiny.fvecs` base (squared-L2
//! distances 3.5 / 7.5 / 43.5 for query 0, reversed order for the
//! others), so [`GroundTruth::compute`] must reproduce it byte for
//! byte through the `.ivecs` reader — the same path `icq gauntlet
//! --gt` trusts for real datasets. Tie-breaking is pinned separately:
//! equal distances rank by ascending id, the canonical `(distance,
//! id)` order every `TopK`-based searcher in the tree shares.

use icq::core::Matrix;
use icq::data::realworld::{read_bvecs, read_fvecs, read_ivecs};
use icq::eval::gauntlet;
use icq::eval::GroundTruth;
use icq::index::{search_exact, OpCounter};

fn fixture(name: &str) -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// The generator must reproduce the committed fixture exactly — every
/// neighbor, in order, for every query.
#[test]
fn compute_matches_committed_fixture_exactly() {
    let base = read_fvecs(fixture("tiny.fvecs")).unwrap();
    let queries = read_bvecs(fixture("tiny.bvecs")).unwrap();
    let gt = GroundTruth::compute(&base, &queries, 3);
    let committed: Vec<Vec<u32>> = read_ivecs(fixture("tiny_gt.ivecs"))
        .unwrap()
        .into_iter()
        .map(|row| row.into_iter().map(|v| v as u32).collect())
        .collect();
    assert_eq!(gt.r, 3);
    assert_eq!(
        gt.ids, committed,
        "brute-force ground truth diverged from the committed fixture"
    );
}

/// `load_data` with explicit files must hand the gauntlet the same
/// truth the fixture commits (base kept as-is, queries and truth rows
/// aligned) — the file-backed path of the `icq gauntlet` CLI.
#[test]
fn gauntlet_file_path_loads_committed_truth() {
    let p = gauntlet::profile_by_name("smoke").unwrap();
    let base = fixture("tiny.fvecs");
    let queries = fixture("tiny.bvecs");
    let gt = fixture("tiny_gt.ivecs");
    let data = gauntlet::load_data(
        &p,
        Some(base.to_str().unwrap()),
        Some(queries.to_str().unwrap()),
        Some(gt.to_str().unwrap()),
    )
    .unwrap();
    assert_eq!(data.base.rows(), 3, "--gt mode must keep the base as-is");
    assert_eq!(data.queries.rows(), 3);
    assert_eq!(data.truth.r, 3);
    assert_eq!(data.truth.ids, vec![vec![0, 1, 2], vec![2, 1, 0], vec![2, 1, 0]]);
}

/// Equal distances rank by ascending id. A database of duplicated rows
/// makes every distance tied, so the truth list *is* the tie-break
/// order — and it must agree bitwise with the exact searcher, which
/// shares the canonical `TopK`.
#[test]
fn tied_distances_rank_by_ascending_id() {
    // rows 0..6 alternate between two identical points: all distances
    // to a query tie within each group of duplicates
    let a = [1.0f32, 2.0, 3.0, 4.0];
    let b = [5.0f32, 1.0, 0.0, 2.0];
    let db = Matrix::from_fn(6, 4, |i, j| if i % 2 == 0 { a[j] } else { b[j] });
    let q = Matrix::from_fn(1, 4, |_, j| a[j] + 0.1);
    let gt = GroundTruth::compute(&db, &q, 6);
    // the three copies of `a` (ids 0,2,4) are nearer; ties ascend by id
    assert_eq!(gt.ids[0], vec![0, 2, 4, 1, 3, 5]);

    // and the exact searcher agrees bitwise (same TopK order)
    let ops = OpCounter::new();
    let exact = search_exact::search_batch(&db, &q, 6, &ops);
    let exact_ids: Vec<u32> = exact[0].iter().map(|h| h.id).collect();
    assert_eq!(gt.ids[0], exact_ids, "GT and exact searcher tie-break differ");
}

/// Truncation: a partial-ranking fixture (`r` smaller than the base)
/// still matches the prefix of a deeper computation — the generator is
/// prefix-stable in `r`.
#[test]
fn truth_is_prefix_stable_in_r() {
    let base = read_fvecs(fixture("tiny.fvecs")).unwrap();
    let queries = read_bvecs(fixture("tiny.bvecs")).unwrap();
    let deep = GroundTruth::compute(&base, &queries, 3);
    let shallow = GroundTruth::compute(&base, &queries, 1);
    for (d, s) in deep.ids.iter().zip(&shallow.ids) {
        assert_eq!(&d[..1], &s[..], "top-1 differs from the top-3 prefix");
    }
}
