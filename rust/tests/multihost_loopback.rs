//! Multi-host loopback integration: spawn two real `icq shard-server`
//! *processes* on 127.0.0.1 serving exported shard snapshots, gather
//! over them (plus one in-process local shard) from this process, and
//! assert the result is bitwise identical to the flat single-process
//! path — the end-to-end proof that the serving topology survives a
//! process (and therefore a host) boundary. CI runs this test as its
//! own step.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use icq::config::SearchConfig;
use icq::coordinator::{
    BatchSearcher, LocalShardBackend, NativeSearcher, RemoteShardBackend,
    ShardBackend, ShardedSearcher,
};
use icq::core::{Matrix, Rng};
use icq::index::shard::{ShardPolicy, ShardedIndex};
use icq::index::{EncodedIndex, OpCounter};
use icq::quantizer::icq::{Icq, IcqOpts};

/// Kill the child on drop so failed asserts don't leak servers.
struct ServerProc(Child);

impl Drop for ServerProc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn `icq shard-server --index <snapshot>` on an ephemeral port and
/// read the bound address back off its stdout.
fn spawn_shard_server(snapshot: &std::path::Path) -> (ServerProc, String) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_icq"))
        .args([
            "shard-server",
            "--addr",
            "127.0.0.1:0",
            "--index",
            snapshot.to_str().unwrap(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn shard-server");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    for _ in 0..50 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.trim().strip_prefix("[shard-server] listening on ")
        {
            addr = Some(rest.to_string());
            break;
        }
    }
    let addr = addr.expect("shard-server never announced its address");
    (ServerProc(child), addr)
}

#[test]
#[ignore = "spawns real server processes; run via the dedicated CI step \
            (cargo test --test multihost_loopback -- --ignored)"]
fn two_processes_plus_local_shard_match_flat_bitwise() {
    // deterministic index, small enough to train quickly
    let n = 330;
    let mut rng = Rng::new(41);
    let x = Matrix::from_fn(n, 16, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    });
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 6, prior_steps: 100, seed: 7 },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..n as i32).collect());
    let sharded = ShardedIndex::build(&index, ShardPolicy::Count(3)).unwrap();
    assert_eq!(sharded.num_shards(), 3);

    // export shards 0 and 1 as standalone snapshots
    let dir = std::env::temp_dir()
        .join(format!("icq_multihost_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut servers = Vec::new();
    let mut addrs = Vec::new();
    for s in [0usize, 1] {
        let path = dir.join(format!("shard{s}.icqf"));
        sharded.shard_pack(s).save(&path).unwrap();
        let (proc_, addr) = spawn_shard_server(&path);
        servers.push(proc_);
        addrs.push(addr);
    }

    // gather: two remote shard-server processes + one local shard
    let cfg = SearchConfig::default();
    let ops = Arc::new(OpCounter::new());
    let mut backends: Vec<Box<dyn ShardBackend>> = Vec::new();
    for (s, addr) in addrs.iter().enumerate() {
        let remote = RemoteShardBackend::connect_with_timeout(
            addr,
            cfg,
            Duration::from_secs(20),
        )
        .unwrap_or_else(|e| panic!("connecting to shard {s}: {e:#}"));
        assert_eq!(remote.hello().start, sharded.spec(s).start);
        backends.push(Box::new(remote));
    }
    backends.push(Box::new(LocalShardBackend::new(
        sharded.spec(2).start,
        sharded.shard(2).clone(),
        cfg,
        ops.clone(),
    )));
    let searcher = ShardedSearcher::from_backends(
        backends,
        Some(sharded.shard(2).clone()),
        index.dim(),
        ops,
    )
    .unwrap();

    // flat single-process baseline through the same serving surface
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    let mut qrng = Rng::new(43);
    let qs = Matrix::from_fn(6, 16, |_, j| {
        qrng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    });
    for top_k in [1usize, 10, 200] {
        let got = searcher.search_batch(&qs, top_k).unwrap();
        let want = flat.search_batch(&qs, top_k).unwrap();
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g, w,
                "top_k={top_k} query {qi}: multi-process gather diverged \
                 from the flat index"
            );
        }
    }

    drop(servers); // kill the children before cleaning their snapshots
    let _ = std::fs::remove_dir_all(&dir);
}
