//! PJRT runtime integration: execute the AOT-exported JAX/Pallas graphs
//! and verify numeric parity with the native rust math, then run the full
//! bundle-driven search path. Tests skip (with a notice) when artifacts
//! have not been built — run `make artifacts` first.

use icq::core::Matrix;
use icq::data::loader::TrainedBundle;
use icq::index::lut::{Lut, LutContext};
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::{search_adc, EncodedIndex, OpCounter};
use icq::quantizer::Codebooks;
use icq::runtime::XlaRuntime;

fn runtime() -> Option<XlaRuntime> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (make artifacts)");
        return None;
    }
    // Artifacts may exist while the PJRT backend does not (the xla crate
    // is stubbed in sandboxed builds): skip for that case only — any
    // other init failure with artifacts present is a real regression.
    match XlaRuntime::new(&dir) {
        Ok(rt) => Some(rt),
        Err(e) if format!("{e:#}").contains("PJRT backend not built") => {
            eprintln!("SKIP: PJRT backend stubbed out ({e:#})");
            None
        }
        Err(e) => panic!("runtime init failed with artifacts present: {e:#}"),
    }
}

fn bundle(rt: &XlaRuntime) -> TrainedBundle {
    TrainedBundle::load(
        rt.artifacts.param_path("trained_linear_synth").unwrap(),
    )
    .expect("bundle load")
}

#[test]
fn pjrt_lut_matches_native_lut() {
    let Some(rt) = runtime() else { return };
    let b = bundle(&rt);
    let cb = Codebooks::from_vec(b.k, b.m, b.d, b.codebooks.clone());
    let ctx = LutContext::new(&cb);
    let nq = rt.batch().min(4);
    let queries = Matrix::from_fn(nq, b.d, |i, j| b.embeddings.get(i, j));
    let luts = rt
        .lut_batch(cb.as_slice(), b.k, b.m, b.d, &queries)
        .expect("pjrt lut");
    for (qi, flat) in luts.iter().enumerate() {
        let native = Lut::build(&ctx, &cb, queries.row(qi));
        for kk in 0..b.k {
            for j in 0..b.m {
                let got = flat[kk * b.m + j];
                let want = native.get(kk, j);
                assert!(
                    (got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "lut[{qi}][{kk},{j}]: pjrt {got} native {want}"
                );
            }
        }
    }
}

#[test]
fn pjrt_scan_matches_native_crude_sum() {
    let Some(rt) = runtime() else { return };
    let b = bundle(&rt);
    let cb = Codebooks::from_vec(b.k, b.m, b.d, b.codebooks.clone());
    let ctx = LutContext::new(&cb);
    let batch = rt.batch();
    let queries = Matrix::from_fn(batch, b.d, |i, j| b.embeddings.get(i, j));
    let luts = rt
        .lut_batch(cb.as_slice(), b.k, b.m, b.d, &queries)
        .expect("pjrt lut");
    // pad codes to scan_n
    let scan_n = rt.scan_n();
    let n_use = b.n.min(scan_n);
    let mut codes = vec![0i32; scan_n * b.k];
    codes[..n_use * b.k].copy_from_slice(&b.codes[..n_use * b.k]);
    // flatten luts back to [batch, K, m]
    let mut lut_flat = vec![0.0f32; batch * b.k * b.m];
    for (qi, flat) in luts.iter().enumerate() {
        lut_flat[qi * b.k * b.m..(qi + 1) * b.k * b.m].copy_from_slice(flat);
    }
    for fast_k in rt.artifacts.manifest.fast_ks.clone() {
        if fast_k > b.k {
            continue;
        }
        let crude = rt
            .scan(fast_k, &lut_flat, batch, b.k, b.m, &codes)
            .expect("pjrt scan");
        // compare a sample of entries vs native partial sums
        for qi in (0..batch).step_by(5) {
            let native_lut =
                Lut::from_flat(b.k, b.m, luts[qi].clone());
            for i in (0..n_use).step_by(97) {
                let row: Vec<u16> = (0..b.k)
                    .map(|kk| b.codes[i * b.k + kk] as u16)
                    .collect();
                let want = native_lut.partial_sum(&row, 0, fast_k);
                let got = crude[qi * scan_n + i];
                assert!(
                    (got - want).abs() < 1e-2 * want.abs().max(1.0),
                    "scan_f{fast_k}[{qi},{i}]: pjrt {got} native {want}"
                );
            }
        }
    }
}

#[test]
fn bundle_index_two_step_search_is_consistent() {
    let Some(rt) = runtime() else { return };
    let b = bundle(&rt);
    let index = EncodedIndex::from_bundle(&b).expect("index from bundle");
    assert_eq!(index.len(), b.n);
    assert!(index.fast_k >= 1 && index.fast_k < index.k());
    let ops = OpCounter::new();
    let ops_lean = OpCounter::new();
    // queries = first few database embeddings (self-retrieval sanity)
    for qi in 0..5 {
        let q = b.embeddings.row(qi);
        let icq_hits = search_icq::search(
            &index,
            q,
            IcqSearchOpts { k: 10, margin_scale: 1.0 },
            &ops,
        );
        let adc_hits = search_adc::search(&index, q, 10, &ops);
        // two-step == full ADC distances (group-orthogonal codebooks)
        for (a, b2) in icq_hits.iter().zip(&adc_hits) {
            assert!(
                (a.dist - b2.dist).abs() < 1e-2,
                "two-step {} vs adc {}",
                a.dist,
                b2.dist
            );
        }
        // margin 0 is lossless under hard orthogonality (see
        // prop_two_step_equals_full_adc) and must actually prune
        let lean_hits = search_icq::search(
            &index,
            q,
            IcqSearchOpts { k: 10, margin_scale: 0.0 },
            &ops_lean,
        );
        for (a, b2) in lean_hits.iter().zip(&adc_hits) {
            assert!(
                (a.dist - b2.dist).abs() < 1e-2,
                "lean two-step {} vs adc {}",
                a.dist,
                b2.dist
            );
        }
    }
    // Cost shape: never MORE than the K adds/vector of full ADC. How much
    // less depends on how strongly the gradient-joint training concentrated
    // variance into psi — weak on this easily-separable synthetic workload
    // (EXPERIMENTS.md section Learned-bundle notes); the classical rust
    // trainer's pruning power is asserted in integration_pipeline.
    assert!(
        ops_lean.avg_ops_per_candidate() <= index.k() as f64 + 1e-9,
        "margin-0 two-step exceeded K adds/vector (got {:.3})",
        ops_lean.avg_ops_per_candidate()
    );
}

#[test]
fn pipeline_linear_graph_runs_raw_queries() {
    let Some(rt) = runtime() else { return };
    let b = bundle(&rt);
    let (w_dims, w) = b.pack.f32("embed.w").expect("embed weights");
    let (_, bias) = b.pack.f32("embed.b").expect("embed bias");
    let d_in = w_dims[0];
    let nq = 4;
    let queries = Matrix::from_fn(nq, d_in, |i, j| b.test_x.get(i, j));
    let luts = rt
        .pipeline_linear(
            w,
            bias,
            d_in,
            &b.codebooks,
            b.k,
            b.m,
            b.d,
            &queries,
        )
        .expect("fused pipeline");
    assert_eq!(luts.len(), nq);
    // parity: embed natively then build the native LUT
    let wm = Matrix::from_vec(d_in, b.d, w.to_vec());
    let cb = Codebooks::from_vec(b.k, b.m, b.d, b.codebooks.clone());
    let ctx = LutContext::new(&cb);
    for qi in 0..nq {
        let mut z = queries.select_rows(&[qi]).matmul(&wm);
        for (v, bb) in z.row_mut(0).iter_mut().zip(bias) {
            *v += bb;
        }
        let native = Lut::build(&ctx, &cb, z.row(0));
        for kk in 0..b.k {
            for j in (0..b.m).step_by(17) {
                let got = luts[qi][kk * b.m + j];
                let want = native.get(kk, j);
                assert!(
                    (got - want).abs() < 2e-2 * want.abs().max(1.0),
                    "pipeline lut[{qi}][{kk},{j}]: {got} vs {want}"
                );
            }
        }
    }
}
