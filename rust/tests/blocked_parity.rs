//! Blocked-scan parity suite: the book-major dense sweeps must return
//! the same distances as the serial row-major two-step across every
//! quantizer in the zoo (PQ / OPQ / CQ / SQ / ICQ) and the edge shapes
//! the blocked layout has to handle — n not divisible by the block size,
//! fast_k == K (non-ICQ indexes), top-k = 1, single-book indexes, and
//! the empty index. The narrow (u8) store must match the wide (u16)
//! store bitwise, and the quantized-LUT crude sweep must stay a lower
//! bound of the f32 crude sums while returning the same top-k within
//! 1e-3.

use icq::core::{Matrix, Rng};
use icq::data::format::TensorPack;
use icq::data::Dataset;
use icq::index::blocked::BlockedCodes;
use icq::index::lut::Lut;
use icq::index::qlut::{self, QLut};
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::{search_adc, EncodedIndex, OpCounter};
use icq::quantizer::cq::{Cq, CqOpts};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::opq::{Opq, OpqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use icq::quantizer::sq::{Sq, SqOpts};

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

/// For each query row: blocked full ADC == row-major oracle, and blocked
/// scanfirst == serial two-step, distances within 1e-3.
fn assert_parity(index: &EncodedIndex, queries: &Matrix, top_k: usize) {
    let ops = OpCounter::new();
    for qi in 0..queries.rows() {
        let lut = Lut::build(index.lut_ctx(), index.codebooks(), queries.row(qi));

        let adc_blocked = search_adc::search_with_lut(index, &lut, top_k, &ops);
        let adc_oracle =
            search_adc::search_with_lut_rowmajor(index, &lut, top_k, &ops);
        assert_eq!(adc_blocked.len(), adc_oracle.len());
        for (a, b) in adc_blocked.iter().zip(&adc_oracle) {
            assert!(
                (a.dist - b.dist).abs() < 1e-3,
                "q{qi}: blocked ADC {} vs row-major {}",
                a.dist,
                b.dist
            );
        }

        let opts = IcqSearchOpts { k: top_k, margin_scale: 1.0 };
        let serial = search_icq::search_with_lut(index, &lut, opts, &ops);
        let scan = search_icq::search_scanfirst(index, &lut, opts, &ops);
        assert_eq!(serial.len(), scan.len());
        for (a, b) in serial.iter().zip(&scan) {
            assert!(
                (a.dist - b.dist).abs() < 1e-3,
                "q{qi}: serial two-step {} vs blocked scanfirst {}",
                a.dist,
                b.dist
            );
        }

        // quantized crude sweep: same top-k within tolerance (falls back
        // to the f32 sweep transparently on wide indexes)
        let mut crude = Vec::new();
        let qscan = search_icq::search_scanfirst_qlut(
            index, &lut, opts, &ops, &mut crude,
        );
        assert_eq!(serial.len(), qscan.len());
        for (a, b) in serial.iter().zip(&qscan) {
            assert!(
                (a.dist - b.dist).abs() < 1e-3,
                "q{qi}: serial two-step {} vs qlut scanfirst {}",
                a.dist,
                b.dist
            );
        }

        // the quantized crude sums themselves must be lower bounds of
        // the f32 crude sums, within the documented error band
        if let Some(b8) = index.blocked().as_u8() {
            let fk = index.fast_k.min(index.k());
            if QLut::fits(fk) && index.len() > 0 {
                let qlut = QLut::from_lut(&lut, 0, fk);
                let mut lb = vec![f32::NAN; index.len()];
                qlut::crude_sums_into(b8, &qlut, &mut lb);
                for i in 0..index.len() {
                    let exact =
                        lut.partial_sum(index.codes().row(i), 0, fk);
                    assert!(
                        lb[i] <= exact + 1e-4,
                        "q{qi} vec {i}: quantized crude {} above f32 {exact}",
                        lb[i]
                    );
                    assert!(
                        exact - lb[i] <= qlut.max_err() + 1e-4,
                        "q{qi} vec {i}: error {} above bound {}",
                        exact - lb[i],
                        qlut.max_err()
                    );
                }
            }
        }
    }
}

fn queries(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, _| rng.normal_f32())
}

#[test]
fn parity_pq_tail_block() {
    // 101 vectors: one full block + a 37-lane tail
    let x = hetero(101, 8, 1);
    let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 6, seed: 0 });
    let idx = EncodedIndex::build(&pq, &x, vec![0; 101]);
    assert_eq!(idx.fast_k, idx.k()); // fast_k == K edge for non-ICQ
    assert_parity(&idx, &queries(5, 8, 11), 10);
}

#[test]
fn parity_pq_single_book_and_top1() {
    let x = hetero(70, 6, 2);
    let pq = Pq::train(&x, PqOpts { k: 1, m: 8, iters: 6, seed: 0 });
    let idx = EncodedIndex::build(&pq, &x, vec![0; 70]);
    assert_eq!(idx.k(), 1);
    assert_parity(&idx, &queries(4, 6, 12), 1);
}

#[test]
fn parity_opq() {
    let x = hetero(90, 8, 3);
    let opq = Opq::train(
        &x,
        OpqOpts { pq: PqOpts { k: 4, m: 8, iters: 4, seed: 1 }, outer_iters: 2 },
    );
    let idx = EncodedIndex::build(&opq, &x, vec![0; 90]);
    assert_parity(&idx, &queries(4, 8, 13), 10);
}

#[test]
fn parity_cq() {
    let x = hetero(80, 8, 4);
    let cq = Cq::train(
        &x,
        CqOpts { k: 3, m: 8, iters: 3, icm_sweeps: 1, seed: 2 },
    );
    let idx = EncodedIndex::build(&cq, &x, vec![0; 80]);
    assert_parity(&idx, &queries(4, 8, 14), 10);
}

#[test]
fn parity_sq_embedded_queries() {
    let x = hetero(70, 10, 5);
    let y: Vec<i32> = (0..70).map(|i| (i % 3) as i32).collect();
    let data = Dataset::new(x, y.clone());
    let sq = Sq::train(
        &data,
        SqOpts {
            d_out: 6,
            cq: CqOpts { k: 2, m: 8, iters: 3, icm_sweeps: 1, seed: 3 },
            ridge: 1e-3,
        },
    );
    let idx = EncodedIndex::build(&sq, &data.x, y);
    // the SQ index lives in the embedded space; queries must be embedded
    let qz = sq.embed(&queries(4, 10, 15));
    assert_parity(&idx, &qz, 10);
}

#[test]
fn parity_icq_multiple_shapes() {
    for (n, d, k, m, fast_k, seed) in [
        (130usize, 16usize, 8usize, 16usize, 2usize, 6u64), // tail of 2
        (64, 12, 4, 8, 1, 7),                               // exactly one block
        (40, 8, 2, 8, 1, 8),                                // sub-block index
    ] {
        let x = hetero(n, d, seed);
        let icq = Icq::train(
            &x,
            IcqOpts { k, m, fast_k, kmeans_iters: 5, prior_steps: 80, seed },
        );
        let idx = EncodedIndex::build_icq(&icq, &x, vec![0; n]);
        assert!(idx.fast_k < idx.k());
        assert_parity(&idx, &queries(4, d, seed + 20), 10);
        assert_parity(&idx, &queries(2, d, seed + 40), 1); // top-k = 1
    }
}

#[test]
fn parity_empty_index() {
    let x = hetero(60, 8, 9);
    let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 4, seed: 0 });
    let empty = EncodedIndex::build(&pq, &Matrix::zeros(0, 8), vec![]);
    assert_eq!(empty.len(), 0);
    assert_eq!(empty.blocked().num_blocks(), 0);
    assert_parity(&empty, &queries(3, 8, 16), 5);
    // explicit: both paths return no hits
    let lut = Lut::build(empty.lut_ctx(), empty.codebooks(), &[0.0; 8]);
    let ops = OpCounter::new();
    assert!(search_adc::search_with_lut(&empty, &lut, 5, &ops).is_empty());
    assert!(search_icq::search_scanfirst(
        &empty,
        &lut,
        IcqSearchOpts::default(),
        &ops
    )
    .is_empty());
}

/// Randomized u8-vs-u16 storage parity: the two widths hold the same
/// codes and produce bitwise-identical f32 partial sums, across tail
/// blocks and the m == 256 boundary (the largest codebook u8 can index).
#[test]
fn u8_and_u16_blocked_sweeps_bitwise_equal() {
    for (n, k, m, seed) in [
        (130usize, 8usize, 256usize, 1u64), // m == 256 boundary, tail of 2
        (65, 4, 16, 2),                     // tail of 1
        (64, 3, 200, 3),                    // exactly one block
        (19, 2, 2, 4),                      // sub-block index
    ] {
        let mut rng = Rng::new(seed);
        let code_data: Vec<u16> =
            (0..n * k).map(|_| rng.below(m) as u16).collect();
        let codes = icq::quantizer::Codes::from_vec(n, k, code_data);
        let lut_data: Vec<f32> =
            (0..k * m).map(|_| rng.uniform_f32() * 3.0).collect();
        let lut = Lut::from_flat(k, m, lut_data);
        let narrow = BlockedCodes::<u8>::from_codes(&codes);
        let wide = BlockedCodes::<u16>::from_codes(&codes);
        for (k0, k1) in [(0, k), (0, 1), (1, k)] {
            let mut out8 = vec![f32::NAN; n];
            let mut out16 = vec![f32::NAN; n];
            narrow.partial_sums_into(&lut, k0, k1, &mut out8);
            wide.partial_sums_into(&lut, k0, k1, &mut out16);
            for i in 0..n {
                assert_eq!(
                    out8[i], out16[i],
                    "n={n} m={m} i={i} books [{k0},{k1}): widths diverged"
                );
                assert_eq!(
                    out8[i],
                    lut.partial_sum(codes.row(i), k0, k1),
                    "n={n} m={m} i={i}: blocked diverged from oracle"
                );
            }
        }
    }
}

/// Build a real index at the m == 256 boundary straight from a snapshot
/// pack (dense codebooks): the narrow store must be selected and every
/// dense path must agree with the serial oracle.
fn index_from_raw(n: usize, k: usize, m: usize, d: usize, seed: u64) -> EncodedIndex {
    let mut rng = Rng::new(seed);
    let cb: Vec<f32> =
        (0..k * m * d).map(|_| rng.normal_f32()).collect();
    let codes: Vec<i32> =
        (0..n * k).map(|_| rng.below(m) as i32).collect();
    let mut pack = TensorPack::new();
    pack.insert_f32("codebooks", vec![k, m, d], cb);
    pack.insert_i32("codes", vec![n, k], codes);
    pack.insert_i32("fast_k", vec![1], vec![1]);
    pack.insert_f32("sigma", vec![1], vec![0.5]);
    pack.insert_i32("labels", vec![n], vec![0; n]);
    EncodedIndex::from_pack(&pack).expect("valid raw snapshot")
}

#[test]
fn parity_m256_boundary_selects_u8() {
    let idx = index_from_raw(150, 3, 256, 6, 30);
    assert_eq!(idx.m(), 256);
    assert_eq!(idx.blocked().code_width_bits(), 8);
    assert!(idx.blocked().as_u8().is_some());
    assert_parity(&idx, &queries(4, 6, 31), 10);
}

#[test]
fn parity_m_above_256_selects_u16() {
    let idx = index_from_raw(100, 2, 300, 4, 32);
    assert_eq!(idx.blocked().code_width_bits(), 16);
    assert!(idx.blocked().as_u8().is_none());
    // qlut entry point must fall back to the f32 sweep and still agree
    assert_parity(&idx, &queries(3, 4, 33), 5);
}

/// The scanfirst path must never pay more refine adds than refining
/// everything, and its op accounting must match the serial path's crude
/// cost exactly (n * fast_k crude adds per query).
#[test]
fn scanfirst_op_accounting() {
    let n = 150;
    let x = hetero(n, 12, 10);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 4, m: 8, fast_k: 1, kmeans_iters: 5, prior_steps: 80, seed: 10 },
    );
    let idx = EncodedIndex::build_icq(&icq, &x, vec![0; n]);
    let q: Vec<f32> = queries(1, 12, 17).row(0).to_vec();
    let lut = Lut::build(idx.lut_ctx(), idx.codebooks(), &q);
    let ops = OpCounter::new();
    search_icq::search_scanfirst(&idx, &lut, IcqSearchOpts::default(), &ops);
    let s = ops.snapshot();
    assert_eq!(s.queries, 1);
    assert_eq!(s.candidates, n as u64);
    let crude_adds = (n * idx.fast_k) as u64;
    let max_refine_adds = (n * (idx.k() - idx.fast_k)) as u64;
    assert!(s.table_adds >= crude_adds);
    assert!(s.table_adds <= crude_adds + max_refine_adds);
    assert_eq!(s.refined, (s.table_adds - crude_adds) / (idx.k() - idx.fast_k) as u64);
}
