//! Property suite for the recall metric and the gauntlet sweep.
//!
//! Each property here is *provable* for the code under test, not
//! merely observed on one lucky seed:
//!
//! * `recall_at` is a mean of per-query fractions in [0, 1], so it
//!   stays in [0, 1] for arbitrary inputs — duplicates, empty rows,
//!   `r` past either list;
//! * a searcher that returns the ground truth itself scores exactly
//!   1.0 (the oracle fixed point);
//! * IVF recall against the flat quantized ranking is monotone
//!   non-decreasing in `nprobe` (probed cell sets are nested: a
//!   flat-top-k row, once probed, is beaten by at most k-1 rows
//!   anywhere, so it can never drop out at a larger probe) and exactly
//!   1.0 at the full probe;
//! * for lower-bound families (crude sum <= full sum) the serial
//!   two-step returns the *same* result at every `fast_k` — entering
//!   the final top-k requires the full distance to beat the threshold
//!   at arrival, and the crude lower bound beats it first — so recall
//!   vs the flat scan is constant 1.0, hence monotone in `fast_k`;
//! * two same-seed gauntlet runs are bitwise identical once the
//!   timing-only `qps` fields are stripped ([`gauntlet::stable_subset`]).

use icq::core::{Hit, Matrix, Rng};
use icq::eval::gauntlet;
use icq::eval::{recall_at, GroundTruth};
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::{EncodedIndex, IvfBuildOpts, IvfIndex, OpCounter};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::pq::{Pq, PqOpts};

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

fn queries(nq: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

fn ids_of(results: &[Vec<Hit>]) -> Vec<Vec<u32>> {
    results
        .iter()
        .map(|hits| hits.iter().map(|h| h.id).collect())
        .collect()
}

/// Arbitrary adversarial inputs — duplicate ids, empty rows, truth
/// longer and shorter than the result list — must keep recall in
/// [0, 1] for every cutoff.
#[test]
fn recall_stays_in_unit_interval_on_arbitrary_inputs() {
    let mut rng = Rng::new(99);
    for trial in 0..50u64 {
        let nq = 1 + rng.below(6);
        let results: Vec<Vec<Hit>> = (0..nq)
            .map(|_| {
                (0..rng.below(12))
                    .map(|rank| Hit {
                        id: rng.below(8) as u32, // dense id range => duplicates
                        dist: rank as f32,
                    })
                    .collect()
            })
            .collect();
        let truth: Vec<Vec<u32>> = (0..nq)
            .map(|_| (0..rng.below(12)).map(|_| rng.below(8) as u32).collect())
            .collect();
        for r in [0usize, 1, 3, 10, 100] {
            let v = recall_at(&results, &truth, r);
            assert!(
                (0.0..=1.0).contains(&v),
                "trial {trial} r={r}: recall {v} out of [0,1]"
            );
        }
    }
}

/// The oracle fixed point: handing the exact ground truth back as the
/// result list must score exactly 1.0 at every cutoff that the truth
/// covers — no floating-point slack.
#[test]
fn oracle_searcher_scores_exactly_one() {
    let base = hetero(300, 16, 21);
    let qs = queries(12, 16, 22);
    let truth = GroundTruth::compute(&base, &qs, 20);
    let as_results: Vec<Vec<Hit>> = truth
        .ids
        .iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .map(|(rank, &id)| Hit { id, dist: rank as f32 })
                .collect()
        })
        .collect();
    for r in [1usize, 5, 10, 20] {
        assert_eq!(
            recall_at(&as_results, &truth.ids, r),
            1.0,
            "oracle recall@{r} must be exactly 1.0"
        );
    }
}

/// IVF recall@10 against the flat quantized ranking is monotone
/// non-decreasing in `nprobe` and exactly 1.0 at the full probe —
/// measured through the same `recall_at` the gauntlet reports, so the
/// committed `recall10_vs_flat` trajectory inherits the property.
#[test]
fn ivf_recall_vs_flat_is_monotone_in_nprobe() {
    let x = hetero(500, 16, 31);
    let icq = Icq::train(
        &x,
        IcqOpts {
            k: 8,
            m: 16,
            fast_k: 2,
            kmeans_iters: 5,
            prior_steps: 80,
            seed: 31,
        },
    );
    let index =
        EncodedIndex::build_icq(&icq, &x, (0..500).map(|i| i as i32).collect());
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 12, iters: 6, seed: 0 },
    )
    .unwrap();
    let qs = queries(10, 16, 32);
    let ops = OpCounter::new();
    let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
    let flat_ids = ids_of(&search_icq::search_batch(&index, &qs, opts, &ops));
    let mut prev = -1.0f64;
    for nprobe in [1usize, 2, 4, 8, 12] {
        let res = ivf.search_batch(&qs, nprobe, opts, &ops);
        let recall = recall_at(&res, &flat_ids, 10);
        assert!(
            recall >= prev,
            "recall@10 vs flat dropped {prev} -> {recall} at nprobe {nprobe}"
        );
        prev = recall;
    }
    assert_eq!(prev, 1.0, "full probe must recover the flat top-10 exactly");
}

/// Lower-bound families: the serial two-step returns the flat scan's
/// exact result at *every* `fast_k`, so recall vs flat is constant 1.0
/// across the sweep — the strongest form of "monotone non-decreasing
/// in fast_k". Checked for ICQ (sigma > 0, margin gate) and PQ
/// (sigma = 0, margin 0, strict lower bound).
#[test]
fn fast_k_sweep_is_lossless_for_lower_bound_families() {
    let x = hetero(400, 16, 41);
    let labels: Vec<i32> = (0..400).map(|i| i as i32).collect();
    let qs = queries(8, 16, 42);
    let ops = OpCounter::new();
    let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };

    let icq = Icq::train(
        &x,
        IcqOpts {
            k: 8,
            m: 16,
            fast_k: 8,
            kmeans_iters: 5,
            prior_steps: 80,
            seed: 41,
        },
    );
    let pq = Pq::train(&x, PqOpts { k: 8, m: 16, iters: 4, seed: 41 });
    let indexes = [
        ("icq", EncodedIndex::build_icq(&icq, &x, labels.clone())),
        ("pq", EncodedIndex::build(&pq, &x, labels)),
    ];
    for (name, index) in indexes {
        let mut full = index.clone();
        full.fast_k = full.k();
        full.sigma = 0.0;
        let flat_ids =
            ids_of(&search_icq::search_batch(&full, &qs, opts, &ops));
        let mut prev = -1.0f64;
        for fk in [1usize, 2, 4, 8] {
            let mut idx = index.clone();
            idx.fast_k = fk;
            let res = search_icq::search_batch(&idx, &qs, opts, &ops);
            let recall = recall_at(&res, &flat_ids, 10);
            assert!(
                recall >= prev,
                "{name}: recall vs flat dropped {prev} -> {recall} at \
                 fast_k {fk}"
            );
            assert_eq!(
                recall, 1.0,
                "{name}: fast_k={fk} must be lossless for a lower-bound \
                 family"
            );
            prev = recall;
        }
    }
}

/// Two same-seed gauntlet runs must agree bitwise on everything except
/// wall-clock throughput: strip `qps` and compare the serialized
/// artifacts byte for byte. This is the determinism contract the
/// committed BENCH baselines (and `cargo xtask bench-check`) rely on.
#[test]
fn same_seed_gauntlet_runs_are_bitwise_stable() {
    let p = gauntlet::profile_by_name("smoke").unwrap();
    let run = || {
        let data = gauntlet::load_data(&p, None, None, None).unwrap();
        gauntlet::run(&p, &data).unwrap()
    };
    let (a, b) = (run(), run());
    for (name, x, y) in [
        ("recall", &a.recall, &b.recall),
        ("serving", &a.serving, &b.serving),
        ("kernels", &a.kernels, &b.kernels),
    ] {
        assert_eq!(
            gauntlet::stable_subset(x).to_string_json(),
            gauntlet::stable_subset(y).to_string_json(),
            "BENCH_{name} differs across same-seed runs"
        );
    }
}
