//! Chaos suite: every resilience behavior of the remote-shard serving
//! layer — hedged retries, error failover, circuit breaking + health
//! probes, pool pipelining, transparent redial, server-side idle
//! timeouts and connection caps — exercised deterministically through
//! the scripted fault-injecting proxy in `tests/support/chaos_proxy.rs`
//! (faults fire on exact frame indexes, not on wall-clock luck).
//!
//! The core acceptance assertions: with a 2-replica remote shard,
//! black-holing or killing the primary mid-batch still returns results
//! **bitwise identical** to the flat path within the configured
//! deadline (no hang, no partial top-k), and a slowloris connection
//! against a `--idle-timeout` server is reaped without disturbing a
//! concurrent healthy client.

mod support;

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use icq::config::SearchConfig;
use icq::coordinator::wire::{self, Frame, ServeShardOpts, WireError};
use icq::coordinator::{
    BatchSearcher, LocalShardBackend, NativeSearcher, PoolOpts,
    RemoteMetrics, RemoteShardBackend, ReplicaOpts, ReplicaSetBackend,
    ShardBackend, ShardJob, ShardedSearcher,
};
use icq::core::{Matrix, Rng};
use icq::index::shard::{ShardPolicy, ShardedIndex};
use icq::index::{EncodedIndex, OpCounter};
use icq::quantizer::icq::{Icq, IcqOpts};

use support::chaos_proxy::{ChaosProxy, Fault};

fn icq_index(n: usize, seed: u64) -> EncodedIndex {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(n, 16, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    });
    let icq = Icq::train(
        &x,
        IcqOpts {
            k: 8,
            m: 16,
            fast_k: 2,
            kmeans_iters: 5,
            prior_steps: 80,
            seed,
        },
    );
    EncodedIndex::build_icq(&icq, &x, (0..n as i32).collect())
}

fn queries(nq: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, 16, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

/// Serve `index` on an ephemeral loopback port from a detached thread.
fn spawn_server(index: EncodedIndex, start: usize) -> String {
    spawn_server_with(index, start, ServeShardOpts::default())
}

fn spawn_server_with(
    index: EncodedIndex,
    start: usize,
    opts: ServeShardOpts,
) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let _ = wire::serve_shard_with(listener, Arc::new(index), start, opts);
    });
    addr
}

fn job(qs: &Matrix, top_k: usize) -> ShardJob {
    ShardJob {
        queries: Arc::new(qs.clone()),
        luts: Arc::new(Vec::new()),
        top_k,
        filter: None,
    }
}

fn pool(io_timeout: Duration, retries: usize) -> PoolOpts {
    PoolOpts {
        size: 2,
        connect_timeout: Duration::from_secs(10),
        io_timeout,
        retries,
    }
}

/// Acceptance: a black-holed primary must not stall the gather — the
/// hedge fires, the replica answers, and the merged top-k stays
/// bitwise identical to the flat path.
#[test]
fn hedge_fires_on_blackholed_primary_and_results_match_flat_bitwise() {
    let index = icq_index(300, 21);
    let sharded = ShardedIndex::build(&index, ShardPolicy::Count(2)).unwrap();
    assert_eq!(sharded.num_shards(), 2);
    let cfg = SearchConfig::default();

    // shard 0 behind two "replicas": the primary routed through a proxy
    // that black-holes its first reply, the second dialed directly
    let upstream =
        spawn_server(sharded.shard(0).as_ref().clone(), sharded.spec(0).start);
    let proxy = ChaosProxy::spawn(
        upstream.clone(),
        vec![vec![Fault::Pass, Fault::BlackHole]],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let set = ReplicaSetBackend::connect(
        &[proxy.addr().to_string(), upstream.clone()],
        cfg,
        pool(Duration::from_secs(3), 1),
        ReplicaOpts {
            hedge_after: Duration::from_millis(50),
            deadline: Duration::from_secs(30),
            circuit_failures: 100,
            probe_interval: Duration::ZERO,
        },
        metrics.clone(),
    )
    .unwrap();
    assert_eq!(set.num_replicas(), 2);

    let ops = Arc::new(OpCounter::new());
    let backends: Vec<Box<dyn ShardBackend>> = vec![
        Box::new(set),
        Box::new(LocalShardBackend::new(
            sharded.spec(1).start,
            sharded.shard(1).clone(),
            cfg,
            ops.clone(),
        )),
    ];
    let searcher = ShardedSearcher::from_backends(
        backends,
        Some(sharded.shard(1).clone()),
        index.dim(),
        ops,
    )
    .unwrap();
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);

    let qs = queries(4, 22);
    // batch 1: primary's reply is black-holed -> the hedge must win
    let got = searcher.search_batch(&qs, 7).unwrap();
    let want = flat.search_batch(&qs, 7).unwrap();
    assert_eq!(got, want, "hedged gather diverged from flat");
    assert!(
        metrics.hedges.load(Ordering::Relaxed) >= 1,
        "hedge never fired: {}",
        metrics.summary()
    );
    assert!(
        metrics.hedge_wins.load(Ordering::Relaxed) >= 1,
        "hedge never won: {}",
        metrics.summary()
    );

    // batch 2 (steady state): the proxy's script is exhausted, so a
    // fresh primary connection passes everything through
    let got = searcher.search_batch(&qs, 50).unwrap();
    let want = flat.search_batch(&qs, 50).unwrap();
    assert_eq!(got, want, "post-chaos gather diverged from flat");
}

/// Acceptance: killing the primary mid-batch (connection dropped while
/// the reply is in flight, and refused on redial) fails over to the
/// replica with bitwise-identical results — no hang, no partial top-k.
#[test]
fn failover_on_primary_killed_mid_batch_matches_flat_bitwise() {
    let index = icq_index(220, 23);
    let cfg = SearchConfig::default();
    let upstream = spawn_server(index.clone(), 0);
    // conn 0: greet, then kill the connection on the first reply;
    // conn 1 (the transparent redial): kill at the hello
    let proxy = ChaosProxy::spawn(
        upstream.clone(),
        vec![vec![Fault::Pass, Fault::Disconnect], vec![Fault::Disconnect]],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let mut set = ReplicaSetBackend::connect(
        &[proxy.addr().to_string(), upstream.clone()],
        cfg,
        pool(Duration::from_secs(3), 1),
        ReplicaOpts {
            // hedge timer long on purpose: recovery must come from the
            // error-triggered failover, not the clock
            hedge_after: Duration::from_secs(20),
            deadline: Duration::from_secs(30),
            circuit_failures: 100,
            probe_interval: Duration::ZERO,
        },
        metrics.clone(),
    )
    .unwrap();

    let qs = queries(3, 24);
    let started = Instant::now();
    let got = set.search(&job(&qs, 9)).unwrap();
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    let want = flat.search_batch(&qs, 9).unwrap();
    assert_eq!(got, want, "failover result diverged from flat");
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "failover waited on the hedge timer instead of the error"
    );
    assert_eq!(
        metrics.failovers.load(Ordering::Relaxed),
        1,
        "{}",
        metrics.summary()
    );
    assert_eq!(
        metrics.redials.load(Ordering::Relaxed),
        1,
        "mid-stream kill on the pooled connection earns one redial: {}",
        metrics.summary()
    );
    assert_eq!(proxy.accepted(), 2, "expected exactly one redial dial");
}

/// Consecutive primary failures open its circuit (traffic flows to the
/// replica without touching the primary); a failed probe keeps it open,
/// a successful probe closes it and traffic returns to the primary.
#[test]
fn circuit_opens_after_failures_and_probe_closes_it() {
    let index = icq_index(200, 25);
    let cfg = SearchConfig::default();
    let upstream = spawn_server(index.clone(), 0);
    // conn 0: die on the first reply; conns 1, 2: die at the hello;
    // conn 3+: healthy again (scripts exhausted -> pass-through)
    let proxy = ChaosProxy::spawn(
        upstream.clone(),
        vec![
            vec![Fault::Pass, Fault::Disconnect],
            vec![Fault::Disconnect],
            vec![Fault::Disconnect],
        ],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let mut set = ReplicaSetBackend::connect(
        &[proxy.addr().to_string(), upstream.clone()],
        cfg,
        // retries = 0: every connection-level failure surfaces to the
        // replica layer, making the failure accounting exact
        pool(Duration::from_secs(3), 0),
        ReplicaOpts {
            hedge_after: Duration::ZERO, // no hedge timer: errors only
            deadline: Duration::from_secs(30),
            circuit_failures: 2,
            // long interval: the background prober can't interfere and
            // the open circuit cannot half-open mid-test
            probe_interval: Duration::from_secs(120),
        },
        metrics.clone(),
    )
    .unwrap();
    let handle = set.handle();
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    let qs = queries(3, 26);
    let want = flat.search_batch(&qs, 8).unwrap();

    // batch 1: pooled conn 0 dies mid-reply -> failure #1 -> failover
    assert_eq!(set.search(&job(&qs, 8)).unwrap(), want);
    assert!(!handle.circuit_open(0));
    // batch 2: fresh dial (conn 1) dies at hello -> failure #2 -> open
    assert_eq!(set.search(&job(&qs, 8)).unwrap(), want);
    assert!(handle.circuit_open(0), "{}", metrics.summary());
    assert_eq!(metrics.circuit_opens.load(Ordering::Relaxed), 1);
    assert_eq!(proxy.accepted(), 2);

    // batch 3: circuit open -> the replica serves, primary untouched
    assert_eq!(set.search(&job(&qs, 8)).unwrap(), want);
    assert_eq!(
        proxy.accepted(),
        2,
        "an open circuit must not dial the primary"
    );

    // probe 1 lands on conn 2 (still scripted to die): circuit stays
    // open
    handle.probe_now();
    assert!(handle.circuit_open(0));
    assert_eq!(metrics.probe_failures.load(Ordering::Relaxed), 1);
    // probe 2 lands on conn 3 (healthy): circuit closes
    handle.probe_now();
    assert!(!handle.circuit_open(0), "{}", metrics.summary());
    assert_eq!(metrics.circuit_closes.load(Ordering::Relaxed), 1);
    assert_eq!(proxy.accepted(), 4);

    // batch 4: primary serves again, over the connection the probe
    // left warm in the pool
    assert_eq!(set.search(&job(&qs, 8)).unwrap(), want);
    assert_eq!(proxy.accepted(), 4, "probe's connection was not reused");
}

/// The pool really pipelines: two concurrent exchanges on one endpoint
/// each get their own connection (one reused, one dialed), and both
/// return correct results.
#[test]
fn pool_runs_two_exchanges_in_flight_on_separate_connections() {
    let index = icq_index(180, 27);
    let cfg = SearchConfig::default();
    let upstream = spawn_server(index.clone(), 0);
    // the pooled connection's first reply is held 1.5 s — a wide margin
    // over thread-scheduling jitter — guaranteeing the second exchange
    // overlaps the first and must dial its own connection
    let proxy = ChaosProxy::spawn(
        upstream,
        vec![vec![Fault::Pass, Fault::Delay(Duration::from_millis(1500))]],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let remote = RemoteShardBackend::connect_pooled(
        proxy.addr(),
        cfg,
        pool(Duration::from_secs(5), 1),
        metrics.clone(),
    )
    .unwrap();
    let endpoint = remote.endpoint().clone();

    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    let qa = queries(2, 28);
    let qb = queries(2, 29);
    let want_a = flat.search_batch(&qa, 6).unwrap();
    let want_b = flat.search_batch(&qb, 6).unwrap();

    let barrier = Arc::new(Barrier::new(2));
    let mut handles = Vec::new();
    for qs in [qa, qb] {
        let endpoint = endpoint.clone();
        let barrier = barrier.clone();
        let j = job(&qs, 6);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            endpoint.search_job(&j)
        }));
    }
    let results: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(results[0].as_ref().unwrap(), &want_a);
    assert_eq!(results[1].as_ref().unwrap(), &want_b);
    assert_eq!(
        metrics.dials.load(Ordering::Relaxed),
        2,
        "two in-flight exchanges must use two connections: {}",
        metrics.summary()
    );
    assert_eq!(proxy.accepted(), 2);
}

/// A corrupted reply frame injected in flight surfaces as a checksum
/// error and is never blindly retried.
#[test]
fn corrupted_frame_in_flight_is_a_structured_checksum_error() {
    let index = icq_index(160, 31);
    let cfg = SearchConfig::default();
    let upstream = spawn_server(index, 0);
    let proxy = ChaosProxy::spawn(
        upstream,
        vec![vec![Fault::Pass, Fault::CorruptBit]],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let mut remote = RemoteShardBackend::connect_pooled(
        proxy.addr(),
        cfg,
        pool(Duration::from_secs(5), 1),
        metrics.clone(),
    )
    .unwrap();
    let qs = queries(1, 32);
    let err = remote.search(&job(&qs, 5)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum"), "got: {msg}");
    assert_eq!(
        metrics.redials.load(Ordering::Relaxed),
        0,
        "protocol corruption must not be redialed"
    );
}

/// An unanswerable replica set fails the batch at the configured
/// deadline with a structured error — bounded latency, not a hang.
#[test]
fn unanswered_batch_fails_at_the_deadline_not_the_io_timeout() {
    let index = icq_index(150, 33);
    let cfg = SearchConfig::default();
    let upstream = spawn_server(index, 0);
    let proxy = ChaosProxy::spawn(
        upstream,
        vec![vec![Fault::Pass, Fault::BlackHole]],
    );
    let metrics = Arc::new(RemoteMetrics::new());
    let mut set = ReplicaSetBackend::connect(
        &[proxy.addr().to_string()],
        cfg,
        // io timeout far beyond the deadline: only the deadline can
        // unblock the caller
        pool(Duration::from_secs(60), 1),
        ReplicaOpts {
            hedge_after: Duration::ZERO,
            deadline: Duration::from_millis(400),
            circuit_failures: 0,
            probe_interval: Duration::ZERO,
        },
        metrics.clone(),
    )
    .unwrap();
    let qs = queries(2, 34);
    let started = Instant::now();
    let err = set.search(&job(&qs, 4)).unwrap_err();
    let elapsed = started.elapsed();
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline"), "got: {msg}");
    assert!(
        elapsed < Duration::from_secs(30),
        "deadline did not bound the wait ({elapsed:?})"
    );
    assert_eq!(metrics.deadline_exceeded.load(Ordering::Relaxed), 1);
}

/// Acceptance: a slowloris connection against `--idle-timeout` is
/// reaped without disturbing a concurrent healthy client — whose pooled
/// connection, reaped while idle between batches, is replaced by a
/// transparent redial (zero client-visible errors).
#[test]
fn idle_timeout_reaps_slowloris_while_healthy_client_is_undisturbed() {
    let index = icq_index(170, 35);
    let cfg = SearchConfig::default();
    let idle = Duration::from_millis(150);
    let addr = spawn_server_with(
        index.clone(),
        0,
        ServeShardOpts { idle_timeout: Some(idle), max_conns: 0 },
    );

    // slowloris: greet, then trickle 3 bytes of a frame and stall
    let slow_addr = addr.clone();
    let slowloris = std::thread::spawn(move || {
        let sock = TcpStream::connect(&slow_addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(20))).ok();
        let mut reader = sock.try_clone().unwrap();
        let hello = wire::read_frame(&mut reader).unwrap();
        assert!(matches!(hello, Frame::Hello(_)));
        use std::io::Write as _;
        (&sock).write_all(b"IC\x00").unwrap();
        // the server must reap us: first a goodbye naming the stall
        // (we are mid-frame, not idle), then EOF
        match wire::read_frame(&mut reader) {
            Ok(Frame::Error { message }) => {
                assert!(
                    message.contains("timed out"),
                    "unexpected goodbye: {message}"
                );
                // after the goodbye the connection must be gone
                assert!(wire::read_frame(&mut reader).is_err());
            }
            // the goodbye can race the close; EOF alone also proves
            // the reap
            Err(WireError::Closed | WireError::Truncated(_)) => {}
            other => panic!("expected reap, got {other:?}"),
        }
    });

    // healthy client, concurrently: three batches with idle gaps
    // longer than the server's timeout between them
    let metrics = Arc::new(RemoteMetrics::new());
    let mut remote = RemoteShardBackend::connect_pooled(
        &addr,
        cfg,
        pool(Duration::from_secs(5), 1),
        metrics.clone(),
    )
    .unwrap();
    let flat = NativeSearcher::new(Arc::new(index.clone()), cfg);
    let qs = queries(2, 36);
    let want = flat.search_batch(&qs, 6).unwrap();
    for round in 0..3 {
        let got = remote
            .search(&job(&qs, 6))
            .unwrap_or_else(|e| panic!("round {round} failed: {e:#}"));
        assert_eq!(got, want, "round {round} diverged");
        std::thread::sleep(idle + Duration::from_millis(150));
    }
    assert!(
        metrics.redials.load(Ordering::Relaxed) >= 1,
        "server reaping never exercised the redial path: {}",
        metrics.summary()
    );
    slowloris.join().unwrap();
}

/// `--max-conns` turns away excess connections with a structured error
/// frame and admits new ones as slots free up.
#[test]
fn connection_cap_refuses_excess_and_recovers_when_a_slot_frees() {
    let index = icq_index(140, 37);
    let addr = spawn_server_with(
        index,
        0,
        ServeShardOpts { idle_timeout: None, max_conns: 2 },
    );
    let dial = |addr: &str| -> (TcpStream, Result<Frame, WireError>) {
        let sock = TcpStream::connect(addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).ok();
        let mut reader = sock.try_clone().unwrap();
        let frame = wire::read_frame(&mut reader);
        (sock, frame)
    };
    let (c1, f1) = dial(&addr);
    assert!(matches!(f1, Ok(Frame::Hello(_))), "conn 1: {f1:?}");
    let (_c2, f2) = dial(&addr);
    assert!(matches!(f2, Ok(Frame::Hello(_))), "conn 2: {f2:?}");
    // third connection: structured refusal instead of a hello
    let (_c3, f3) = dial(&addr);
    match f3 {
        Ok(Frame::Error { message }) => {
            assert!(message.contains("connection limit"), "{message}")
        }
        other => panic!("expected a connection-limit error, got {other:?}"),
    }
    // free a slot and poll until the server admits a new connection
    drop(c1);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_c, f) = dial(&addr);
        if matches!(f, Ok(Frame::Hello(_))) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "freed slot never became admittable; last answer: {f:?}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}
