//! Mapped-snapshot parity suite: the icqfmt2 zero-copy open must be
//! invisible to search.
//!
//! An index reopened through `MappedPack::open` (a real file, a real
//! mapping) holds the same codes, labels, and block-major transpose as
//! the owned build — as file-backed views instead of heap copies — and
//! one LUT context derived from the same codebook floats. Every
//! distance is therefore the same f32 arithmetic in the same scan
//! order, so top-k results must be **bitwise** equal, not just close.
//! This suite pins that across all five quantizer families (flat), the
//! IVF coarse partition at partial and full probes, the sharded
//! scatter-gather over mapped-loaded shards, tail blocks (n not a
//! multiple of the 64-row code block), and the u8 -> u16 code-width
//! boundary (m > 256).

use std::path::PathBuf;
use std::sync::Arc;

use icq::config::SearchConfig;
use icq::coordinator::{
    BatchSearcher, LocalShardBackend, NativeSearcher, ShardBackend,
    ShardedSearcher,
};
use icq::core::{Hit, Matrix, Rng};
use icq::data::mapped::{save_mapped, MappedPack};
use icq::data::Dataset;
use icq::index::ivf::load_index_mapped;
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::shard::load_shard_mapped;
use icq::index::{
    AnyIndex, EncodedIndex, IvfBuildOpts, IvfIndex, OpCounter, ShardPolicy,
    ShardedIndex,
};
use icq::quantizer::cq::{Cq, CqOpts};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::opq::{Opq, OpqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use icq::quantizer::sq::{Sq, SqOpts};

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

fn queries(nq: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

/// One index per quantizer family (same construction as the IVF parity
/// suite); `vectors` live in the index's own coordinate space.
fn method_indexes(
    n: usize,
    seed: u64,
) -> Vec<(&'static str, EncodedIndex, Matrix)> {
    let x = hetero(n, 16, seed);
    let labels: Vec<i32> = (0..n).map(|i| i as i32).collect();
    let mut out = Vec::new();

    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 5, prior_steps: 80, seed },
    );
    out.push(("icq", EncodedIndex::build_icq(&icq, &x, labels.clone()), x.clone()));

    let pq = Pq::train(&x, PqOpts { k: 4, m: 16, iters: 4, seed });
    out.push(("pq", EncodedIndex::build(&pq, &x, labels.clone()), x.clone()));

    let opq = Opq::train(
        &x,
        OpqOpts { pq: PqOpts { k: 4, m: 16, iters: 4, seed }, outer_iters: 2 },
    );
    let mut opq_idx = EncodedIndex::build(&opq, &x, labels.clone());
    opq_idx.sigma = 0.0;
    out.push(("opq", opq_idx, x.clone()));

    let cq = Cq::train(
        &x,
        CqOpts { k: 4, m: 16, iters: 3, icm_sweeps: 2, seed },
    );
    out.push(("cq", EncodedIndex::build(&cq, &x, labels.clone()), x.clone()));

    let y: Vec<i32> = (0..n).map(|i| (i % 4) as i32).collect();
    let sq = Sq::train(
        &Dataset::new(x.clone(), y),
        SqOpts {
            d_out: 8,
            cq: CqOpts { k: 4, m: 16, iters: 3, icm_sweeps: 2, seed },
            ridge: 1e-3,
        },
    );
    let emb = sq.embed(&x);
    out.push(("sq", EncodedIndex::build(&sq, &x, labels), emb));
    out
}

/// Per-query two-step top-k (the serial heap path both sides share).
fn flat_topk(index: &EncodedIndex, qs: &Matrix, k: usize) -> Vec<Vec<Hit>> {
    let ops = OpCounter::new();
    let mut scratch = Vec::new();
    (0..qs.rows())
        .map(|qi| {
            search_icq::search_scanfirst_query_qlut(
                index,
                qs.row(qi),
                IcqSearchOpts { k, margin_scale: 1.0 },
                &ops,
                &mut scratch,
            )
        })
        .collect()
}

/// Write `index` as an icqfmt2 file and reopen it through a real
/// mapping (the `--mmap` serving path, not the in-memory shortcut).
fn reopen_mapped(index: &EncodedIndex, tag: &str) -> EncodedIndex {
    let path = temp_path(tag);
    save_mapped(&index.to_mapped_tensors(), &path).unwrap();
    let mp = MappedPack::open(&path).unwrap();
    let back = EncodedIndex::from_mapped(&mp).unwrap();
    std::fs::remove_file(&path).unwrap();
    back
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("icq-mapped-parity-{}-{tag}.icq2", std::process::id()))
}

/// Every family, tail blocks included (330 is not a multiple of the
/// 64-row code block): the mapped reopen holds identical codes, labels,
/// and blocked transpose — as views — and searches bitwise-identically.
#[test]
fn mapped_flat_is_bitwise_for_every_method() {
    for (name, index, x) in method_indexes(330, 21) {
        let back = reopen_mapped(&index, name);
        assert_eq!(back.codes(), index.codes(), "{name}: codes changed");
        assert_eq!(back.labels, index.labels, "{name}: labels changed");
        assert!(back.labels.is_mapped(), "{name}: labels were copied");
        assert!(back.blocked().is_mapped(), "{name}: blocked was copied");

        let qs = queries(5, x.cols(), 22);
        assert_eq!(
            flat_topk(&back, &qs, 10),
            flat_topk(&index, &qs, 10),
            "{name}: mapped top-k != owned top-k"
        );
    }
}

/// The IVF coarse partition survives the mapped round trip at partial
/// and full probes — per-cell code lists and id maps are file views,
/// the probe order and merged `(distance, id)` heap are unchanged.
#[test]
fn mapped_ivf_is_bitwise_at_every_nprobe() {
    let (_, index, x) = method_indexes(330, 23).swap_remove(0);
    let qs = queries(5, 16, 24);
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 7, iters: 6, seed: 0 },
    )
    .unwrap();

    let path = temp_path("ivf");
    save_mapped(&ivf.to_mapped_tensors(), &path).unwrap();
    let mp = MappedPack::open(&path).unwrap();
    let AnyIndex::Ivf(back) = load_index_mapped(&mp).unwrap() else {
        panic!("IVF snapshot dispatched as flat");
    };
    std::fs::remove_file(&path).unwrap();

    let ops = OpCounter::new();
    for nprobe in [1usize, 3, 7] {
        for qi in 0..qs.rows() {
            let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
            assert_eq!(
                back.search(qs.row(qi), nprobe, opts, &ops),
                ivf.search(qs.row(qi), nprobe, opts, &ops),
                "nprobe {nprobe} query {qi} diverged under mmap"
            );
        }
    }
}

/// Scatter-gather over shards that were each exported, mapped, and
/// reloaded (`export-shards` -> `shard-server --mmap`, in-process) must
/// equal the flat searcher over the owned whole index.
#[test]
fn mapped_shard_gather_is_bitwise() {
    let (_, index, _) = method_indexes(330, 25).swap_remove(1);
    let qs = queries(6, 16, 26);
    let cfg = SearchConfig { top_k: 10, ..SearchConfig::default() };

    let cut = ShardedIndex::build(&index, ShardPolicy::Count(3)).unwrap();
    let ops = Arc::new(OpCounter::new());
    let mut backends: Vec<Box<dyn ShardBackend>> = Vec::new();
    let mut lut_source = None;
    for s in 0..cut.num_shards() {
        let path = temp_path(&format!("shard{s}"));
        save_mapped(&cut.shard_mapped_tensors(s), &path).unwrap();
        let mp = MappedPack::open(&path).unwrap();
        let (shard, start) = load_shard_mapped(&mp).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert_eq!(start, cut.spec(s).start, "shard {s} placement changed");
        assert!(shard.blocked().is_mapped(), "shard {s} was copied");
        let shard = Arc::new(shard);
        if lut_source.is_none() {
            lut_source = Some(shard.clone());
        }
        backends.push(Box::new(LocalShardBackend::new(
            start,
            shard,
            cfg,
            ops.clone(),
        )));
    }
    let gather = ShardedSearcher::from_backends(
        backends,
        lut_source,
        index.dim(),
        ops,
    )
    .unwrap();
    let flat = NativeSearcher::new(Arc::new(index), cfg);
    assert_eq!(
        gather.search_batch(&qs, 10).unwrap(),
        flat.search_batch(&qs, 10).unwrap(),
        "mapped shard gather != owned flat searcher"
    );
}

/// m > 256 forces the u16 blocked transpose; the mapped container
/// stores and reopens it at that width (the `blocked_u16` tensor), and
/// search stays bitwise across the width boundary.
#[test]
fn mapped_u16_width_boundary_is_bitwise() {
    let n = 330;
    let x = hetero(n, 8, 27);
    let pq = Pq::train(&x, PqOpts { k: 2, m: 300, iters: 2, seed: 27 });
    let index =
        EncodedIndex::build(&pq, &x, (0..n).map(|i| i as i32).collect());
    assert!(
        index.codes().as_slice().iter().any(|&c| c > u8::MAX as u16),
        "corpus too tame: no code crossed the u8 boundary"
    );
    let pack = index.to_mapped_tensors();
    assert!(pack.tensors.contains_key("blocked_u16"));
    assert!(!pack.tensors.contains_key("blocked_u8"));

    let back = reopen_mapped(&index, "wide");
    assert_eq!(back.codes(), index.codes());
    assert!(back.blocked().is_mapped());
    let qs = queries(4, 8, 28);
    assert_eq!(
        flat_topk(&back, &qs, 10),
        flat_topk(&index, &qs, 10),
        "u16-width mapped top-k != owned top-k"
    );
}
