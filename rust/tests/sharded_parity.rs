//! Sharded scatter-gather parity suite: the sharded serving core must
//! return *identical* `(distance, id)`-ordered top-k to the flat
//! single-shard path — not merely close — across shard counts (1/2/7),
//! unaligned and tail-block shard boundaries, empty shards, top-k
//! larger than a shard, ICQ (sigma > 0) and PQ (fast_k == K) indexes,
//! and the wide-m f32 fallback. Also asserts the batched LUT-major
//! sweep is bitwise equal to the per-query sweep through the public
//! serving surface.
//!
//! Why exactness is the right bar: every executor selects hits through
//! the canonical `(distance, id)` top-k, shards recompute the same f32
//! distances as the flat scan (same LUT values, same books-ascending
//! accumulation), and the eq. 11 margin makes the two-step prune
//! lossless — so flat and sharded both reduce to "the k smallest
//! `(distance, id)` pairs of the database" and must agree bit for bit.

use icq::config::SearchConfig;
use icq::coordinator::{BatchSearcher, NativeSearcher, ShardedSearcher};
use icq::core::{Hit, Matrix, Rng};
use icq::data::format::TensorPack;
use icq::index::shard::{ShardPolicy, ShardedIndex};
use icq::index::{EncodedIndex, OpCounter};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use std::sync::Arc;

fn hetero(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(n, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
    })
}

fn icq_index(n: usize, seed: u64) -> EncodedIndex {
    let x = hetero(n, 16, seed);
    let icq = Icq::train(
        &x,
        IcqOpts { k: 8, m: 16, fast_k: 2, kmeans_iters: 6, prior_steps: 100, seed },
    );
    EncodedIndex::build_icq(&icq, &x, (0..n).map(|i| i as i32).collect())
}

fn pq_index(n: usize, seed: u64) -> EncodedIndex {
    let x = hetero(n, 16, seed);
    let pq = Pq::train(&x, PqOpts { k: 4, m: 16, iters: 5, seed });
    EncodedIndex::build(&pq, &x, (0..n).map(|i| i as i32).collect())
}

fn queries(nq: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(nq, d, |_, j| {
        rng.normal_f32() * if j % 4 == 0 { 2.0 } else { 0.5 }
    })
}

/// Flat baseline through the same serving surface (NativeSearcher).
fn flat_results(
    index: &EncodedIndex,
    qs: &Matrix,
    top_k: usize,
) -> Vec<Vec<Hit>> {
    let s = NativeSearcher::new(
        Arc::new(index.clone()),
        SearchConfig::default(),
    );
    s.search_batch(qs, top_k).unwrap()
}

fn assert_identical(
    flat: &[Vec<Hit>],
    sharded: &[Vec<Hit>],
    label: &str,
) {
    assert_eq!(flat.len(), sharded.len(), "{label}: batch size mismatch");
    for (qi, (f, s)) in flat.iter().zip(sharded).enumerate() {
        assert_eq!(
            f, s,
            "{label}: query {qi} sharded top-k != flat top-k"
        );
    }
}

#[test]
fn sharded_matches_flat_across_shard_counts() {
    let index = icq_index(600, 1);
    let qs = queries(6, 16, 2);
    let flat = flat_results(&index, &qs, 10);
    for shards in [1usize, 2, 7] {
        let s = ShardedSearcher::from_index(
            &index,
            ShardPolicy::Count(shards),
            SearchConfig::default(),
        )
        .unwrap();
        let got = s.search_batch(&qs, 10).unwrap();
        assert_identical(&flat, &got, &format!("{shards} shards"));
    }
}

#[test]
fn sharded_matches_flat_on_pq_index() {
    // fast_k == K, sigma == 0: the crude pass IS the full distance
    let index = pq_index(400, 3);
    let qs = queries(5, 16, 4);
    let flat = flat_results(&index, &qs, 8);
    for shards in [2usize, 5] {
        let s = ShardedSearcher::from_index(
            &index,
            ShardPolicy::Count(shards),
            SearchConfig::default(),
        )
        .unwrap();
        assert_identical(&flat, &s.search_batch(&qs, 8).unwrap(), "pq sharded");
    }
}

/// Unaligned cuts, a 1-vector shard, empty shards, and boundaries
/// crossing the flat index's tail block must all merge back exactly.
#[test]
fn sharded_matches_flat_with_irregular_boundaries() {
    let index = icq_index(599, 5);
    let qs = queries(4, 16, 6);
    let flat = flat_results(&index, &qs, 12);
    for cuts in [
        vec![0usize, 64, 65, 300, 599],        // 1-vector shard
        vec![0, 0, 250, 250, 599],             // leading + interior empty
        vec![0, 17, 130, 512, 598, 599],       // unaligned + tail block
        vec![0, 599],                          // single shard, odd n
    ] {
        let sharded = ShardedIndex::from_boundaries(&index, &cuts).unwrap();
        let s = ShardedSearcher::start(sharded, SearchConfig::default());
        assert_identical(
            &flat,
            &s.search_batch(&qs, 12).unwrap(),
            &format!("cuts {cuts:?}"),
        );
    }
}

/// top_k larger than individual shards (and larger than the whole
/// database): every shard contributes everything it has, and the merge
/// must still equal the flat ranking.
#[test]
fn sharded_matches_flat_when_k_exceeds_shard_size() {
    let index = icq_index(150, 7);
    let qs = queries(3, 16, 8);
    // 3 blocks -> 3 shards of <= 64 rows each; ask for 100 > shard size
    let s = ShardedSearcher::from_index(
        &index,
        ShardPolicy::Count(3),
        SearchConfig::default(),
    )
    .unwrap();
    let flat = flat_results(&index, &qs, 100);
    assert_identical(
        &flat,
        &s.search_batch(&qs, 100).unwrap(),
        "k > shard size",
    );

    // k beyond the database: both sides return all 150, same order
    let flat_all = flat_results(&index, &qs, 500);
    let got_all = s.search_batch(&qs, 500).unwrap();
    assert_eq!(got_all[0].len(), 150);
    assert_identical(&flat_all, &got_all, "k > n");
}

/// Wide-m (u16 codes) indexes take the f32 fallback sweep inside every
/// shard; parity must hold there too.
#[test]
fn sharded_matches_flat_on_wide_index_fallback() {
    let (n, k, m, d) = (300usize, 3usize, 300usize, 6usize);
    let mut rng = Rng::new(9);
    let cb: Vec<f32> = (0..k * m * d).map(|_| rng.normal_f32()).collect();
    let codes: Vec<i32> = (0..n * k).map(|_| rng.below(m) as i32).collect();
    let mut pack = TensorPack::new();
    pack.insert_f32("codebooks", vec![k, m, d], cb);
    pack.insert_i32("codes", vec![n, k], codes);
    pack.insert_i32("fast_k", vec![1], vec![1]);
    pack.insert_f32("sigma", vec![1], vec![0.5]);
    pack.insert_i32("labels", vec![n], vec![0; n]);
    let index = EncodedIndex::from_pack(&pack).unwrap();
    assert!(index.blocked().as_u8().is_none(), "m=300 must store u16");

    let qs = queries(4, d, 10);
    let flat = flat_results(&index, &qs, 9);
    let s = ShardedSearcher::from_index(
        &index,
        ShardPolicy::Count(4),
        SearchConfig::default(),
    )
    .unwrap();
    assert_identical(
        &flat,
        &s.search_batch(&qs, 9).unwrap(),
        "wide fallback",
    );
}

/// An entirely empty database served sharded: no hits, no panic.
#[test]
fn sharded_empty_database_returns_no_hits() {
    let index = icq_index(100, 11).slice(0, 0);
    let s = ShardedSearcher::start(
        ShardedIndex::build(&index, ShardPolicy::Count(3)).unwrap(),
        SearchConfig::default(),
    );
    let res = s.search_batch(&queries(2, 16, 12), 5).unwrap();
    assert_eq!(res.len(), 2);
    assert!(res.iter().all(|h| h.is_empty()));
}

/// The block-parallel single-query scan is the sharded topology run on
/// scoped threads: with matching cut points (`Count(t)` and `threads =
/// t` derive the same `div_ceil` boundaries) the two must agree bit for
/// bit — same per-block crude kernels, same refine math, same
/// `(distance, id)` merge.
#[test]
fn block_parallel_scan_matches_sharded_gather_bitwise() {
    use icq::index::lut::Lut;
    use icq::index::search_icq::{self, IcqSearchOpts};

    let index = icq_index(500, 21);
    let qs = queries(3, 16, 22);
    let ops = OpCounter::new();
    for threads in [2usize, 3, 7] {
        let sharded = ShardedSearcher::from_index(
            &index,
            ShardPolicy::Count(threads),
            SearchConfig::default(),
        )
        .unwrap();
        let gathered = sharded.search_batch(&qs, 10).unwrap();
        for qi in 0..qs.rows() {
            let lut =
                Lut::build(index.lut_ctx(), index.codebooks(), qs.row(qi));
            let par = search_icq::search_scanfirst_parallel(
                &index,
                &lut,
                IcqSearchOpts { k: 10, margin_scale: 1.0 },
                &ops,
                threads,
            );
            assert_eq!(
                gathered[qi], par,
                "threads={threads} query {qi}: block-parallel scan \
                 diverged from the sharded gather"
            );
        }
    }
}

/// The batched LUT-major sweep vs the per-query path, through the
/// public serving surface: NativeSearcher (batched engine) must be
/// bitwise equal to per-query scanfirst for every batch size, incl.
/// batches above the engine's internal tile (32).
#[test]
fn batched_lut_major_sweep_is_bitwise_equal_to_per_query() {
    let index = icq_index(500, 13);
    let searcher =
        NativeSearcher::new(Arc::new(index.clone()), SearchConfig::default());
    for nq in [1usize, 8, 40] {
        let qs = queries(nq, 16, 14 + nq as u64);
        let batched = searcher.search_batch(&qs, 10).unwrap();
        let ops = OpCounter::new();
        let mut scratch = Vec::new();
        for qi in 0..nq {
            let serial = icq::index::search_icq::search_scanfirst_query_qlut(
                &index,
                qs.row(qi),
                icq::index::search_icq::IcqSearchOpts {
                    k: 10,
                    margin_scale: 1.0,
                },
                &ops,
                &mut scratch,
            );
            assert_eq!(
                batched[qi], serial,
                "batch={nq} query {qi}: LUT-major sweep diverged"
            );
        }
    }
}
