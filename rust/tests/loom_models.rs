//! Exhaustive interleaving models for the coordinator's concurrency
//! hot spots, run under the in-tree model checker (`icq::modelcheck`,
//! the repo's loom stand-in — the vendored registry has no `loom`).
//!
//! Each test explores **every** schedule of a small model built from
//! the exact production types: the primitives come from
//! `coordinator::sync`, whose `Mutex`/`Condvar` turn into schedule
//! points inside `modelcheck::model`. The suite runs on plain
//! `cargo test` and, with a deeper schedule budget, under
//! `RUSTFLAGS="--cfg loom" cargo test --test loom_models`.
//!
//! Modeled invariants:
//! * pool checkout never double-lends a connection and never loses one
//!   ([`IdlePool`]);
//! * circuit-breaker transitions are counted exactly once no matter how
//!   concurrent attempt threads interleave their outcomes
//!   ([`Breaker`]);
//! * the hedge race has exactly one winner, and an attempt's health
//!   outcome is recorded before its answer becomes observable — so
//!   abandoned (hedge-loser) attempts still count toward the breaker;
//! * admission control never exceeds capacity and never loses a wakeup
//!   ([`Admission`]).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use icq::coordinator::backpressure::Admission;
use icq::coordinator::{Breaker, IdlePool};
use icq::modelcheck::sync::{Condvar, Mutex};
use icq::modelcheck::{model, spawn};

/// Two concurrent callers check the single pooled connection out and
/// back in. In every interleaving: at most one caller holds it at a
/// time (checked across a schedule point taken *while* holding), the
/// token is never duplicated or invented, and it survives the round.
#[test]
fn pool_checkout_never_double_lends() {
    model(|| {
        let pool = Arc::new(IdlePool::with_items(1, vec![7u32]));
        let holders = Arc::new(AtomicUsize::new(0));
        // a modeled mutex whose lock/unlock creates a schedule point
        // while the connection is held — overlap must be observable
        let gate = Arc::new(Mutex::new(()));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let holders = Arc::clone(&holders);
                let gate = Arc::clone(&gate);
                spawn(move || {
                    if let Some(conn) = pool.take() {
                        assert_eq!(conn, 7, "pool invented a connection");
                        assert_eq!(
                            holders.fetch_add(1, Ordering::SeqCst),
                            0,
                            "connection lent to two callers at once"
                        );
                        drop(gate.lock().unwrap());
                        holders.fetch_sub(1, Ordering::SeqCst);
                        assert!(pool.put(conn), "cap-1 pool refused the check-in");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(pool.len(), 1, "the pooled connection was lost");
    });
}

/// Two failures (limit 2) race one success. However the outcomes
/// interleave, the open transition is counted at most once, a close is
/// only counted for a circuit that opened, and the final circuit state
/// agrees with the transition counts — the monotone metrics counters
/// (`circuit_opens`/`circuit_closes`) can trust the breaker's booleans.
#[test]
fn breaker_transition_counts_are_consistent_in_every_interleaving() {
    model(|| {
        let now = Instant::now();
        let hold = Duration::from_secs(1);
        let breaker = Arc::new(Breaker::new());
        let opened = Arc::new(AtomicUsize::new(0));
        let closed = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let breaker = Arc::clone(&breaker);
            let opened = Arc::clone(&opened);
            handles.push(spawn(move || {
                if breaker.record_failure(now, 2, hold) {
                    opened.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        {
            let breaker = Arc::clone(&breaker);
            let closed = Arc::clone(&closed);
            handles.push(spawn(move || {
                if breaker.record_success() {
                    closed.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join();
        }
        let opens = opened.load(Ordering::SeqCst);
        let closes = closed.load(Ordering::SeqCst);
        assert!(opens <= 1, "open transition counted {opens} times");
        assert!(closes <= opens, "closed a circuit that never opened");
        if breaker.is_open() {
            // failures landed last: the open was counted, no close was
            assert_eq!((opens, closes), (1, 0));
        } else if opens == 1 {
            // opened mid-race, then the success closed it
            assert_eq!(closes, 1);
        }
    });
}

/// First-canonical-answer-wins cell, the shape of the replica hedge
/// race (replicas serve identical shards, so every attempt offers the
/// same canonical answer and whichever lands first may win).
struct FirstWins {
    slot: Mutex<Option<(usize, u32)>>,
    cv: Condvar,
}

impl FirstWins {
    fn new() -> Self {
        FirstWins { slot: Mutex::new(None), cv: Condvar::new() }
    }

    /// Offer attempt `idx`'s answer; true if it won the race.
    fn offer(&self, idx: usize, answer: u32) -> bool {
        let mut slot = self.slot.lock().unwrap();
        if slot.is_none() {
            *slot = Some((idx, answer));
            self.cv.notify_all();
            true
        } else {
            false
        }
    }

    /// Block until some attempt has won.
    fn wait_winner(&self) -> (usize, u32) {
        let mut slot = self.slot.lock().unwrap();
        loop {
            if let Some(winner) = *slot {
                return winner;
            }
            slot = self.cv.wait(slot).unwrap();
        }
    }
}

/// The hedge race: two attempts record their health outcome and then
/// offer the same canonical answer; the caller takes the first. In
/// every schedule exactly one attempt wins, the winner's outcome is
/// already recorded by the time its answer is observable (the
/// record-then-send order `launch_attempt` relies on), and the
/// abandoned attempt still records its outcome by the time it drains.
#[test]
fn hedge_race_has_one_winner_and_every_outcome_is_recorded() {
    model(|| {
        let cell = Arc::new(FirstWins::new());
        let recorded =
            Arc::new([AtomicBool::new(false), AtomicBool::new(false)]);
        let wins = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..2)
            .map(|idx| {
                let cell = Arc::clone(&cell);
                let recorded = Arc::clone(&recorded);
                let wins = Arc::clone(&wins);
                spawn(move || {
                    // health bookkeeping lands before the send — the
                    // ordering the production attempt thread preserves
                    recorded[idx].store(true, Ordering::SeqCst);
                    if cell.offer(idx, 42) {
                        wins.fetch_add(1, Ordering::SeqCst);
                    }
                })
            })
            .collect();
        let (winner, answer) = cell.wait_winner();
        assert_eq!(answer, 42, "a non-canonical answer won");
        assert!(
            recorded[winner].load(Ordering::SeqCst),
            "winner observable before its outcome was recorded"
        );
        for h in handles {
            h.join();
        }
        assert_eq!(wins.load(Ordering::SeqCst), 1, "must be exactly one winner");
        assert!(
            recorded[0].load(Ordering::SeqCst)
                && recorded[1].load(Ordering::SeqCst),
            "an abandoned attempt skipped its health outcome"
        );
    });
}

/// Admission control (capacity 1) under two competing callers: no
/// schedule ever has two permits out at once (checked across a
/// schedule point taken while holding), no wakeup is lost (a lost
/// `notify_one` would strand the second caller in `admit` — reported
/// as a deadlock), and the capacity is restored afterwards.
#[test]
fn admission_never_exceeds_capacity_and_never_loses_a_wakeup() {
    model(|| {
        let admission = Admission::new(1);
        let inflight = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(Mutex::new(()));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let admission = admission.clone();
                let inflight = Arc::clone(&inflight);
                let gate = Arc::clone(&gate);
                spawn(move || {
                    let permit = admission.admit();
                    assert_eq!(
                        inflight.fetch_add(1, Ordering::SeqCst),
                        0,
                        "two permits in flight with capacity 1"
                    );
                    drop(gate.lock().unwrap());
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    drop(permit);
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(admission.available(), 1, "permit capacity not restored");
    });
}
