//! Randomized property tests over the coordinator-side invariants
//! (routing/batching/state per the deliverable spec). The vendored
//! registry has no proptest, so these are seeded sweeps over the in-tree
//! RNG — shrinkless but broad, with the failing seed printed on panic.

use icq::coordinator::wire::{
    self, Frame, HelloInfo, WireError, WIRE_VERSION,
};
use icq::core::json::Json;
use icq::core::{Hit, Matrix, Metric, Rng, TopK};
use icq::data::format::TensorPack;
use icq::index::ivf::{load_index, AnyIndex, IvfBuildOpts, IvfIndex};
use icq::index::lut::{Lut, LutContext};
use icq::index::search_icq::{self, IcqSearchOpts};
use icq::index::shard::{load_shard_pack, ShardPolicy, ShardedIndex};
use icq::index::{search_adc, EncodedIndex, OpCounter};
use icq::quantizer::icq::{Icq, IcqOpts};
use icq::quantizer::pq::{Pq, PqOpts};
use icq::quantizer::Quantizer;

/// Property: for any heteroscedastic dataset / geometry, the two-step
/// search returns EXACTLY the full-ADC top-k distances (crude is a lower
/// bound of full when codebook groups are orthogonal), while never paying
/// more table-adds.
#[test]
fn prop_two_step_equals_full_adc() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed * 7 + 1);
        let n = 200 + rng.below(400);
        let d = 8 + rng.below(3) * 4;
        let k = [2usize, 4, 8][rng.below(3)];
        let m = [4usize, 8, 16][rng.below(3)];
        let x = Matrix::from_fn(n, d, |_, j| {
            rng.normal_f32() * if j % 4 == 0 { 4.0 } else { 0.3 }
        });
        let icq = Icq::train(
            &x,
            IcqOpts {
                k,
                m,
                fast_k: 1 + rng.below(k - 1),
                kmeans_iters: 4,
                prior_steps: 50,
                seed,
            },
        );
        let index = EncodedIndex::build_icq(&icq, &x, vec![0; n]);
        let ops_icq = OpCounter::new();
        let ops_adc = OpCounter::new();
        for _ in 0..4 {
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let a = search_adc::search(&index, &q, 10, &ops_adc);
            let b = search_icq::search(
                &index,
                &q,
                IcqSearchOpts { k: 10, margin_scale: 1.0 },
                &ops_icq,
            );
            for (ha, hb) in a.iter().zip(&b) {
                assert!(
                    (ha.dist - hb.dist).abs() < 1e-2 * ha.dist.abs().max(1.0),
                    "seed {seed}: adc {} != two-step {}",
                    ha.dist,
                    hb.dist
                );
            }
        }
        assert!(
            ops_icq.snapshot().table_adds <= ops_adc.snapshot().table_adds,
            "seed {seed}: two-step paid more adds than full ADC"
        );
    }
}

/// Property: crude partial sums are monotone non-decreasing in the number
/// of codebooks summed (LUT entries are true squared distances >= 0).
#[test]
fn prop_crude_monotone_in_k() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed + 100);
        let d = 12;
        let k = 6;
        let x = Matrix::from_fn(300, d, |_, j| {
            rng.normal_f32() * if j % 3 == 0 { 3.0 } else { 0.4 }
        });
        let icq = Icq::train(
            &x,
            IcqOpts { k, m: 8, fast_k: 2, kmeans_iters: 3, prior_steps: 50, seed },
        );
        let index = EncodedIndex::build_icq(&icq, &x, vec![0; 300]);
        let ctx = LutContext::new(index.codebooks());
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let lut = Lut::build(&ctx, index.codebooks(), &q);
        for i in (0..index.len()).step_by(29) {
            let row = index.codes().row(i);
            let mut prev = 0.0;
            for kk in 1..=k {
                let s = lut.partial_sum(row, 0, kk);
                assert!(
                    s >= prev - 1e-4,
                    "seed {seed}: partial sums not monotone at vec {i}"
                );
                prev = s;
            }
        }
    }
}

/// Property: ICQ quantization respects hard group-orthogonality — every
/// codeword's support lies entirely inside or outside psi.
#[test]
fn prop_icq_group_orthogonality() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed + 31);
        let d = 10 + rng.below(8);
        let x = Matrix::from_fn(250, d, |_, j| {
            rng.normal_f32() * if j % 5 == 0 { 5.0 } else { 0.3 }
        });
        let k = 3 + rng.below(3);
        let icq = Icq::train(
            &x,
            IcqOpts { k, m: 8, fast_k: 0, kmeans_iters: 3, prior_steps: 80, seed },
        );
        let cb = icq.codebooks();
        for kk in 0..k {
            for &dim in &cb.support_dims(kk) {
                let in_psi = icq.xi[dim as usize] > 0.5;
                let in_fast = kk < icq.fast_k;
                assert_eq!(
                    in_psi, in_fast,
                    "seed {seed}: book {kk} dim {dim} violates eq. 6"
                );
            }
        }
    }
}

/// Property: TopK always equals sort-and-truncate, under random pushes.
#[test]
fn prop_topk_equals_sorted_prefix() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed + 77);
        let n = 1 + rng.below(2000);
        let k = 1 + rng.below(64);
        let dists: Vec<f32> =
            (0..n).map(|_| rng.uniform_f32() * 1e4).collect();
        let mut top = TopK::new(k);
        for (i, &d) in dists.iter().enumerate() {
            top.push(i as u32, d);
        }
        let mut expect = dists.clone();
        expect.sort_by(f32::total_cmp);
        expect.truncate(k);
        let got: Vec<f32> = top.into_sorted().iter().map(|h| h.dist).collect();
        assert_eq!(got, expect, "seed {seed}");
    }
}

/// Property: icqfmt roundtrips arbitrary tensor packs.
#[test]
fn prop_icqfmt_roundtrip() {
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed + 1234);
        let mut pack = TensorPack::new();
        let n_tensors = 1 + rng.below(6);
        for t in 0..n_tensors {
            let ndim = 1 + rng.below(3);
            let dims: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
            let n: usize = dims.iter().product();
            if rng.below(2) == 0 {
                let data: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                pack.insert_f32(&format!("t{t}"), dims, data);
            } else {
                let data: Vec<i32> =
                    (0..n).map(|_| rng.below(1000) as i32 - 500).collect();
                pack.insert_i32(&format!("t{t}"), dims, data);
            }
        }
        let mut buf = Vec::new();
        pack.write_to(&mut buf).unwrap();
        let back = TensorPack::read_from(&mut &buf[..]).unwrap();
        assert_eq!(pack, back, "seed {seed}");
    }
}

/// Property: the JSON layer roundtrips machine-generated trees.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0).round()),
            3 => Json::Str(format!("s{}-\"x\"\n", rng.below(100))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed + 9);
        let v = gen(&mut rng, 3);
        let text = v.to_string_json();
        let back = Json::parse(&text).unwrap_or_else(|e| {
            panic!("seed {seed}: reparse failed: {e}\n{text}")
        });
        assert_eq!(v, back, "seed {seed}: {text}");
    }
}

/// One random wire frame of any kind, with random payload shapes
/// (empty queries, empty hit lists, and empty error strings included).
fn random_frame(rng: &mut Rng) -> Frame {
    let metric = |rng: &mut Rng| match rng.below(3) {
        0 => Metric::L2,
        1 => Metric::InnerProduct,
        _ => Metric::Cosine,
    };
    match rng.below(4) {
        0 => Frame::Hello(HelloInfo {
            dim: rng.below(512),
            shard_len: rng.below(1 << 20),
            start: rng.below(1 << 20),
            fast_k: rng.below(16),
            metric: metric(rng),
        }),
        1 => {
            let nq = rng.below(4);
            let d = 1 + rng.below(8);
            Frame::Query {
                top_k: 1 + rng.below(100),
                fast_k: rng.below(8),
                margin_scale: rng.uniform_f32(),
                metric: metric(rng),
                queries: Matrix::from_fn(nq, d, |_, _| rng.normal_f32()),
                // empty filters (None) and 1-4 word bitmaps both covered
                filter: match rng.below(3) {
                    0 => None,
                    _ => Some(
                        (0..1 + rng.below(4))
                            .map(|_| {
                                (rng.below(1 << 30) as u64) << 32
                                    | rng.below(1 << 30) as u64
                            })
                            .collect(),
                    ),
                },
            }
        }
        2 => Frame::Results {
            hits: (0..rng.below(4))
                .map(|_| {
                    (0..rng.below(6))
                        .map(|_| Hit {
                            id: rng.below(1 << 30) as u32,
                            dist: rng.uniform_f32() * 100.0,
                        })
                        .collect()
                })
                .collect(),
        },
        _ => Frame::Error { message: "e".repeat(rng.below(48)) },
    }
}

fn encode(frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, frame).unwrap();
    buf
}

/// Property: encode -> decode is the identity for arbitrary frame
/// kinds and payload sizes, including frames decoded back-to-back off
/// one stream.
#[test]
fn prop_wire_roundtrip_random_frames() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed + 4000);
        let frames: Vec<Frame> =
            (0..3).map(|_| random_frame(&mut rng)).collect();
        let mut stream = Vec::new();
        for f in &frames {
            let bytes = encode(f);
            assert_eq!(
                wire::read_frame(&mut &bytes[..]).unwrap(),
                *f,
                "seed {seed}"
            );
            stream.extend_from_slice(&bytes);
        }
        // the same frames parse back-to-back off one buffered stream
        let mut r = &stream[..];
        for f in &frames {
            assert_eq!(wire::read_frame(&mut r).unwrap(), *f, "seed {seed}");
        }
        assert_eq!(
            wire::read_frame(&mut r).unwrap_err(),
            WireError::Closed,
            "seed {seed}: stream must end with a clean close"
        );
    }
}

/// Property: flipping any single bit of an encoded frame never yields
/// the original frame back — and for every byte the checksum covers
/// (the kind byte, the payload, and the CRC itself) the error is
/// exactly `ChecksumMismatch`; header bytes map to their own typed
/// errors (magic / version / length).
#[test]
fn prop_wire_single_bit_flip_is_always_detected() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed + 5000);
        let frame = random_frame(&mut rng);
        let bytes = encode(&frame);
        for bit in 0..bytes.len() * 8 {
            let byte = bit / 8;
            let mut corrupt = bytes.clone();
            corrupt[byte] ^= 1 << (bit % 8);
            let res = wire::read_frame(&mut &corrupt[..]);
            let err = match res {
                Err(e) => e,
                Ok(f) => panic!(
                    "seed {seed} bit {bit}: corrupt frame decoded as {f:?}"
                ),
            };
            match byte {
                0..=3 => assert!(
                    matches!(err, WireError::BadMagic(_)),
                    "seed {seed} bit {bit}: {err}"
                ),
                4..=5 => assert!(
                    matches!(
                        err,
                        WireError::VersionMismatch { want: WIRE_VERSION, .. }
                    ),
                    "seed {seed} bit {bit}: {err}"
                ),
                6 => assert_eq!(
                    err,
                    WireError::ChecksumMismatch,
                    "seed {seed} bit {bit}: kind is checksummed"
                ),
                7..=10 => assert!(
                    matches!(
                        err,
                        WireError::Truncated(_)
                            | WireError::FrameTooLarge(_)
                            | WireError::ChecksumMismatch
                    ),
                    "seed {seed} bit {bit} (length field): {err}"
                ),
                _ => assert_eq!(
                    err,
                    WireError::ChecksumMismatch,
                    "seed {seed} bit {bit}: payload/CRC flips must trip \
                     the checksum"
                ),
            }
        }
    }
}

/// Property: truncating an encoded frame at *every* prefix length
/// yields `Closed` (zero bytes) or `Truncated` — never a panic, never
/// a wrong frame.
#[test]
fn prop_wire_truncation_at_every_prefix_is_typed() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed + 6000);
        let frame = random_frame(&mut rng);
        let bytes = encode(&frame);
        for cut in 0..bytes.len() {
            let err = wire::read_frame(&mut &bytes[..cut]).unwrap_err();
            if cut == 0 {
                assert_eq!(err, WireError::Closed, "seed {seed}");
            } else {
                assert!(
                    matches!(err, WireError::Truncated(_)),
                    "seed {seed} cut {cut}: {err}"
                );
            }
        }
        // the untruncated frame still parses (sanity)
        assert_eq!(wire::read_frame(&mut &bytes[..]).unwrap(), frame);
    }
}

fn pq_index(n: usize, seed: u64) -> (EncodedIndex, Matrix) {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(n, 8, |_, _| rng.normal_f32());
    let pq = Pq::train(&x, PqOpts { k: 4, m: 8, iters: 3, seed: 0 });
    let labels = (0..n).map(|i| i as i32).collect();
    (EncodedIndex::build(&pq, &x, labels), x)
}

fn pack_bytes(pack: &TensorPack) -> Vec<u8> {
    let mut buf = Vec::new();
    pack.write_to(&mut buf).unwrap();
    buf
}

/// Property: every shard snapshot roundtrips through icqfmt byte-for-
/// byte and `load_shard_pack` reconstructs the exact placement manifest
/// (global start row, shard length, sliced labels) for any shard count.
#[test]
fn prop_shard_pack_roundtrip_preserves_placement() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 71);
        let n = 150 + rng.below(300);
        let (index, _) = pq_index(n, seed);
        let nshards = 1 + rng.below(4);
        let sharded =
            ShardedIndex::build(&index, ShardPolicy::Count(nshards)).unwrap();
        for s in 0..sharded.num_shards() {
            let pack = sharded.shard_pack(s);
            let bytes = pack_bytes(&pack);
            let back = TensorPack::read_from(&mut &bytes[..]).unwrap();
            assert_eq!(pack, back, "seed {seed} shard {s}");
            let (loaded, start) = load_shard_pack(&back).unwrap();
            let spec = sharded.spec(s);
            assert_eq!(start, spec.start, "seed {seed} shard {s}");
            assert_eq!(loaded.len(), spec.len(), "seed {seed} shard {s}");
            // labels were sliced per shard, so the first label is the
            // shard's global start row (labels are the row ids here)
            if !loaded.is_empty() {
                assert_eq!(
                    loaded.labels[0] as usize,
                    spec.start,
                    "seed {seed} shard {s}"
                );
            }
        }
        // a plain whole-index snapshot (no placement tensors) loads as
        // the degenerate single shard starting at row 0
        let (whole, start) = load_shard_pack(&index.to_pack()).unwrap();
        assert_eq!((whole.len(), start), (n, 0), "seed {seed}");
    }
}

/// Property: corrupt placement manifests are rejected with typed errors
/// — never loaded as silently misnumbered shards.
#[test]
fn prop_shard_pack_manifest_corruption_is_rejected() {
    let (index, x) = pq_index(300, 9);
    let sharded =
        ShardedIndex::build(&index, ShardPolicy::Count(3)).unwrap();
    let good = sharded.shard_pack(1); // non-zero start
    assert!(load_shard_pack(&good).is_ok());

    // negative start
    let mut bad = good.clone();
    bad.insert_i32("shard_start", vec![1], vec![-1]);
    assert!(load_shard_pack(&bad).is_err());

    // total smaller than start + len
    let mut bad = good.clone();
    bad.insert_i32("shard_total", vec![1], vec![1]);
    assert!(load_shard_pack(&bad).is_err());

    // an IVF snapshot is cell-major: loading it as a flat range shard
    // would misnumber every row, so the loader must refuse it outright
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 4, iters: 3, seed: 0 },
    )
    .unwrap();
    assert!(load_shard_pack(&ivf.to_pack()).is_err());
    match load_index(&ivf.to_pack()).unwrap() {
        AnyIndex::Ivf(i) => assert_eq!(i.n_total(), 300),
        AnyIndex::Flat(_) => panic!("ivf pack loaded as flat"),
    }
}

/// Property: every snapshot loader is total under random single-byte
/// corruption and truncation of real serialized snapshots — the
/// deterministic mirror of the `snapshot_pack` fuzz target, run over
/// all three snapshot flavors (flat, shard, IVF).
#[test]
fn prop_snapshot_byte_corruption_never_panics_loaders() {
    let (index, x) = pq_index(120, 3);
    let sharded =
        ShardedIndex::build(&index, ShardPolicy::Count(2)).unwrap();
    let ivf = IvfIndex::partition(
        &index,
        &x,
        IvfBuildOpts { ncells: 3, iters: 3, seed: 0 },
    )
    .unwrap();
    let flavors = [
        pack_bytes(&index.to_pack()),
        pack_bytes(&sharded.shard_pack(1)),
        pack_bytes(&ivf.to_pack()),
    ];
    let mut rng = Rng::new(0xC0FFEE);
    for bytes in &flavors {
        // the pristine snapshot exercises the happy path of the body
        icq::fuzzing::fuzz_snapshot_pack(bytes);
        for _ in 0..300 {
            let mut m = bytes.clone();
            if rng.below(4) == 0 {
                m.truncate(rng.below(m.len() + 1));
            } else {
                let i = rng.below(m.len());
                m[i] ^= 1 + rng.below(255) as u8;
            }
            icq::fuzzing::fuzz_snapshot_pack(&m);
        }
    }
}

/// Property: encoding never increases reconstruction error vs a coarser
/// encoder (greedy baseline), for random dense codebooks.
#[test]
fn prop_quantizer_encode_quality() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 55);
        let x = Matrix::from_fn(150, 8, |_, _| rng.normal_f32());
        let icq = Icq::train(
            &x,
            IcqOpts { k: 4, m: 8, fast_k: 1, kmeans_iters: 4, prior_steps: 50, seed },
        );
        let err = icq.quantization_error(&x);
        let total_var: f32 = x.col_var().iter().sum();
        assert!(
            err < total_var,
            "seed {seed}: quantization error {err} >= data energy {total_var}"
        );
    }
}

/// Property: under a similarity metric, the crude fast-group score plus
/// the per-query tail slack (`Lut::tail_upper_bound`) upper-bounds the
/// full quantized score for EVERY database row — the upper-bound mirror
/// of eq. 11 that makes similarity pruning safe. Checked for inner
/// product and cosine across random geometries and fast-group splits.
#[test]
fn prop_similarity_crude_plus_tail_upper_bounds_full() {
    for seed in 0..8u64 {
        let mut rng = Rng::new(seed * 13 + 5);
        let n = 150 + rng.below(250);
        let d = 8 + rng.below(3) * 4;
        let k = [4usize, 8][rng.below(2)];
        let x = Matrix::from_fn(n, d, |_, j| {
            rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
        });
        let icq = Icq::train(
            &x,
            IcqOpts {
                k,
                m: 8,
                fast_k: 1 + rng.below(k - 1),
                kmeans_iters: 3,
                prior_steps: 40,
                seed,
            },
        );
        let base = EncodedIndex::build_icq(&icq, &x, vec![0; n]);
        for metric in [Metric::InnerProduct, Metric::Cosine] {
            let index = base.clone().with_metric(metric);
            let fast_k = index.fast_k;
            for trial in 0..3 {
                let q: Vec<f32> =
                    (0..d).map(|_| rng.normal_f32()).collect();
                let lut = Lut::build_metric(
                    index.lut_ctx(),
                    index.codebooks(),
                    &q,
                    metric,
                );
                let slack = lut.tail_upper_bound(fast_k, k);
                for i in 0..n {
                    let row = index.codes().row(i);
                    let crude = lut.partial_sum(row, 0, fast_k);
                    let full = lut.partial_sum(row, 0, k);
                    assert!(
                        crude + slack >= full - 1e-4,
                        "seed {seed} {metric} trial {trial} row {i}: \
                         crude {crude} + slack {slack} < full {full}"
                    );
                }
            }
        }
    }
}

/// Property: cosine search with a raw query is bitwise the inner-
/// product search with the unit-normalized query over the same
/// pre-normalized index, whatever the query's magnitude (cosine is IP
/// over unit vectors — the LUT build normalizes, nothing else differs).
#[test]
fn prop_cosine_topk_is_ip_on_normalized_bitwise() {
    use icq::core::distance;
    for seed in 0..6u64 {
        let mut rng = Rng::new(seed + 401);
        let n = 200 + rng.below(200);
        let d = 8 + rng.below(3) * 4;
        let mut x = Matrix::from_fn(n, d, |_, j| {
            rng.normal_f32() * if j % 4 == 0 { 3.0 } else { 0.4 }
        });
        distance::normalize_rows(&mut x);
        let icq = Icq::train(
            &x,
            IcqOpts {
                k: 4,
                m: 8,
                fast_k: 2,
                kmeans_iters: 3,
                prior_steps: 40,
                seed,
            },
        );
        let cos = EncodedIndex::build_icq(&icq, &x, vec![0; n])
            .with_metric(Metric::Cosine);
        let ip = cos.clone().with_metric(Metric::InnerProduct);
        let ops = OpCounter::new();
        let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
        for scale in [0.25f32, 1.0, 7.0] {
            let q: Vec<f32> =
                (0..d).map(|_| rng.normal_f32() * scale).collect();
            let mut qn = q.clone();
            distance::normalize(&mut qn);
            let a = search_icq::search(&cos, &q, opts, &ops);
            let b = search_icq::search(&ip, &qn, opts, &ops);
            assert_eq!(a, b, "seed {seed} scale {scale}");
        }
    }
}

/// Property: filtered search equals post-filtering the unfiltered
/// exhaustive ranking, bitwise, under every metric — plus the two
/// edges: a nothing-allowed filter returns empty lists and an
/// everything-allowed filter is bitwise the unfiltered scan.
#[test]
fn prop_filtered_is_post_filtered_unfiltered_bitwise() {
    use icq::index::RowFilter;
    for seed in 0..5u64 {
        let mut rng = Rng::new(seed + 733);
        let n = 150 + rng.below(200);
        let d = 12;
        let x = Matrix::from_fn(n, d, |_, j| {
            rng.normal_f32() * if j % 3 == 0 { 3.0 } else { 0.4 }
        });
        let icq = Icq::train(
            &x,
            IcqOpts {
                k: 4,
                m: 8,
                fast_k: 2,
                kmeans_iters: 3,
                prior_steps: 40,
                seed,
            },
        );
        let base = EncodedIndex::build_icq(&icq, &x, vec![0; n]);
        let queries = Matrix::from_fn(3, d, |i, j| {
            x.get((i * 11) % n, j) + rng.normal_f32() * 0.1
        });
        let step = (2 + rng.below(4)) as u32;
        let ids: Vec<u32> =
            (0..n as u32).filter(|i| i % step != 0).collect();
        let filter = RowFilter::from_indices(n, &ids);
        for metric in [Metric::L2, Metric::InnerProduct, Metric::Cosine] {
            let idx = base.clone().with_metric(metric);
            let ops = OpCounter::new();
            let mut crude = Vec::new();
            let opts = IcqSearchOpts { k: 10, margin_scale: 1.0 };
            // oracle: exhaustive unfiltered ranking (top_k = n refines
            // every row exactly), post-filtered and truncated
            let full = search_icq::search_scanfirst_batch_filtered(
                &idx,
                &queries,
                IcqSearchOpts { k: n, margin_scale: 1.0 },
                &ops,
                &mut crude,
                None,
            );
            let got = search_icq::search_scanfirst_batch_filtered(
                &idx, &queries, opts, &ops, &mut crude,
                Some(&filter),
            );
            for (qi, hits) in got.iter().enumerate() {
                let want: Vec<Hit> = full[qi]
                    .iter()
                    .copied()
                    .filter(|h| filter.allows(h.id as usize))
                    .take(opts.k)
                    .collect();
                assert_eq!(hits, &want, "seed {seed} {metric} query {qi}");
            }
            let none = search_icq::search_scanfirst_batch_filtered(
                &idx, &queries, opts, &ops, &mut crude,
                Some(&RowFilter::none(n)),
            );
            assert!(
                none.iter().all(Vec::is_empty),
                "seed {seed} {metric}: nothing-allowed filter returned hits"
            );
            let open = search_icq::search_scanfirst_batch_filtered(
                &idx, &queries, opts, &ops, &mut crude,
                Some(&RowFilter::all(n)),
            );
            let unfiltered = search_icq::search_scanfirst_batch_filtered(
                &idx, &queries, opts, &ops, &mut crude, None,
            );
            assert_eq!(
                open, unfiltered,
                "seed {seed} {metric}: all-pass filter != unfiltered"
            );
        }
    }
}
