//! Coordinator integration: full serving stack over real TCP, plus
//! overload/shedding behavior.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use icq::config::{SearchConfig, ServeConfig};
use icq::coordinator::{Coordinator, NativeSearcher, QueryRequest};
use icq::core::json::Json;
use icq::core::{Matrix, Rng};
use icq::index::EncodedIndex;
use icq::quantizer::icq::{Icq, IcqOpts};

fn make_coordinator(cfg: ServeConfig) -> Arc<Coordinator> {
    let mut rng = Rng::new(5);
    let x = Matrix::from_fn(500, 12, |_, j| {
        rng.normal_f32() * if j % 3 == 0 { 3.0 } else { 0.3 }
    });
    let icq = Icq::train(
        &x,
        IcqOpts { k: 4, m: 16, fast_k: 1, kmeans_iters: 6, prior_steps: 100, seed: 0 },
    );
    let index = Arc::new(EncodedIndex::build_icq(&icq, &x, vec![0; 500]));
    let searcher =
        Arc::new(NativeSearcher::new(index, SearchConfig::default()));
    Arc::new(Coordinator::start(searcher, cfg))
}

#[test]
fn tcp_roundtrip_json_protocol() {
    let coord = make_coordinator(ServeConfig::default());
    // bind on an ephemeral port by probing
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    drop(listener);
    let c2 = coord.clone();
    let addr_s = addr.to_string();
    std::thread::spawn(move || {
        let _ = c2.serve_tcp(&addr_s);
    });
    // wait for the listener
    let mut stream = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // valid query
    let vec_json: Vec<String> = (0..12).map(|i| format!("{}", i as f32 * 0.1)).collect();
    writeln!(writer, "{{\"vector\":[{}],\"top_k\":3}}", vec_json.join(",")).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("ids").unwrap().as_arr().unwrap().len(), 3);

    // malformed query -> error object, connection stays usable
    writeln!(writer, "this is not json").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());

    // still alive after the error
    writeln!(writer, "{{\"vector\":[{}]}}", vec_json.join(",")).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("ids").is_some());
}

#[test]
fn sheds_load_when_admission_exhausted() {
    // max_inflight 1 and a single slow worker: concurrent callers must
    // observe rejections rather than unbounded queueing.
    let coord = make_coordinator(ServeConfig {
        max_batch: 1,
        max_wait_us: 10,
        workers: 1,
        max_inflight: 1,
        ..ServeConfig::default()
    });
    let mut rejected = 0;
    let mut ok = 0;
    std::thread::scope(|s| {
        let results: Vec<_> = (0..16)
            .map(|_| {
                let c = coord.clone();
                s.spawn(move || {
                    c.query(QueryRequest {
                        vector: vec![0.1; 12],
                        top_k: 2,
                        filter_ids: None,
                    })
                })
            })
            .collect();
        for h in results {
            match h.join().unwrap() {
                Ok(_) => ok += 1,
                Err(_) => rejected += 1,
            }
        }
    });
    assert!(ok >= 1, "at least some queries must succeed");
    assert_eq!(ok + rejected, 16);
    let shed = coord
        .metrics
        .queries_rejected
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(shed as usize, rejected);
}

#[test]
fn metrics_track_completed_queries() {
    let coord = make_coordinator(ServeConfig {
        max_batch: 8,
        max_wait_us: 100,
        workers: 2,
        max_inflight: 256,
        ..ServeConfig::default()
    });
    for i in 0..20 {
        let v = vec![(i % 5) as f32 * 0.2; 12];
        coord
            .query(QueryRequest { vector: v, top_k: 4, filter_ids: None })
            .unwrap();
    }
    let done = coord
        .metrics
        .queries_done
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(done, 20);
    assert!(coord.metrics.latency_percentile_us(0.5) > 0);
    assert!(coord.metrics.summary().contains("queries=20"));
}
