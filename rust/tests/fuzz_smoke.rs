//! Deterministic fuzz smoke: drives the shared fuzz-target bodies
//! (`icq::fuzzing`) over the committed corpus seeds plus xorshift-
//! derived mutations on every run of the plain test suite. The
//! coverage-guided fuzzers (`rust/fuzz/`) explore further, but this
//! sweep guarantees tier-1 CI exercises the exact robustness contracts
//! the fuzz targets assert — with reproducible inputs.

use std::path::PathBuf;

/// Mutations per corpus seed. Miri interprets ~1000x slower than
/// native, so it sweeps a reduced (but still corpus-complete) set.
const ROUNDS: usize = if cfg!(miri) { 6 } else { 150 };

fn corpus(target: &str) -> Vec<Vec<u8>> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("fuzz/corpus")
        .join(target);
    let mut seeds = Vec::new();
    let entries = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {} missing: {e}", dir.display()));
    let mut paths: Vec<PathBuf> =
        entries.map(|e| e.unwrap().path()).collect();
    paths.sort(); // deterministic order
    for p in paths {
        seeds.push(std::fs::read(&p).unwrap());
    }
    assert!(!seeds.is_empty(), "no seeds committed for {target}");
    seeds
}

/// xorshift64* — tiny deterministic PRNG for mutation choices.
struct XorShift(u64);

impl XorShift {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n.max(1) as u64) as usize
    }
}

/// Sweep `body` over every seed, then over [`ROUNDS`] mutated variants
/// per seed: bit flips, truncations, byte rewrites, and length-changing
/// splices — the cheap mutations that historically shake out parser
/// panics (off-by-one bounds, length-prefix trust, overflow).
fn sweep(target: &str, salt: u64, body: fn(&[u8])) {
    body(&[]);
    let seeds = corpus(target);
    for (si, seed) in seeds.iter().enumerate() {
        body(seed);
        let mut rng = XorShift(salt ^ (si as u64).wrapping_mul(0x9E37_79B9));
        for _ in 0..ROUNDS {
            let mut m = seed.clone();
            match rng.below(4) {
                0 => {
                    // flip one bit
                    if !m.is_empty() {
                        let i = rng.below(m.len());
                        m[i] ^= 1 << rng.below(8);
                    }
                }
                1 => {
                    // truncate
                    let keep = rng.below(m.len() + 1);
                    m.truncate(keep);
                }
                2 => {
                    // rewrite a short window with random bytes
                    if !m.is_empty() {
                        let start = rng.below(m.len());
                        let end = (start + 1 + rng.below(8)).min(m.len());
                        for b in &mut m[start..end] {
                            *b = rng.next() as u8;
                        }
                    }
                }
                _ => {
                    // splice a random-length random chunk somewhere
                    let at = rng.below(m.len() + 1);
                    let extra: Vec<u8> =
                        (0..rng.below(16)).map(|_| rng.next() as u8).collect();
                    m.splice(at..at, extra);
                }
            }
            body(&m);
        }
    }
}

#[test]
fn wire_frame_decode_survives_seed_mutations() {
    sweep("wire_frame", 0xD1CE, icq::fuzzing::fuzz_wire_frame);
}

#[test]
fn vecs_parsers_survive_seed_mutations() {
    sweep("vecs", 0xBEEF, icq::fuzzing::fuzz_vecs);
}

#[test]
fn snapshot_loaders_survive_seed_mutations() {
    sweep("snapshot_pack", 0xF00D, icq::fuzzing::fuzz_snapshot_pack);
}

#[test]
fn mapped_open_survives_seed_mutations() {
    sweep("mapped_open", 0xACED, icq::fuzzing::fuzz_mapped_open);
}
